"""Cross-family overlap scheduling: merged CompiledSchedules (DESIGN.md §15).

The bucket pipeline (§9) and the step co-planner (§14) both overlap a
ReduceScatter with an AllGather — RS-of-bucket-k behind AG-of-bucket-(k-1),
or the RS/AG halves of two different families from `get_step_plan`. Until
now that overlap was an *issuance order* (two schedule launches back to
back, XLA free to reorder); this module turns it into one **merged
schedule**: the two constituents' ppermute rounds interleave round-by-round
over their own independent buffers, so the overlap the planner priced with
the contended model (`cost_model.contended_pair_time` /
`FastEngine.contended_pair_total`) is the overlap that is actually issued.

Key facts the merge leans on:

* The two constituents operate on DISJOINT buffers, so any interleaving
  that preserves each schedule's internal round/fold order is numerically
  identical to sequential execution (tests/test_overlap.py proves this by
  differential + hypothesis sweeps over interleavings).
* A round pair is **coalesced** (issued adjacently, modeled as fully
  overlapped) exactly when its link sets are disjoint. On a single-switch
  axis a device's NIC is its up/down link pair, so link-disjointness of
  two rounds is: no device sends in both AND no device receives in both —
  the same partial-permutation invariant `lower._color_rounds` enforces
  within one round. Shared-link pairs still execute correctly (separate
  ppermutes) but the contended price charges their serialized β/ε.
* Dataflow validity comes from `core.lower`: the constituents were
  validated by `lower_plan`, execution reuses `lower._round_jax` /
  `lower._fold_jax`, and `plan_merge` re-checks the merge-specific
  contract (same axis size, canonical shards, compatible families).

Guard ladder: a `MergedSchedule` is itself a guard rung. A fault (or an
armed `runtime.faults` injector) during the merged launch demotes it —
sticky, registered with `lower._GUARD_REGISTRY` so `reprobe_guards`
re-arms it — and the launch falls back to SEQUENTIAL execution through the
constituents' own `GuardedSchedule` ladders, so compression and faults
keep demoting exactly as before (merged → sequential → per-constituent
compressed → full-precision → flat lax collective).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np

from repro.runtime.metrics import default_metrics
from repro.runtime.trace import default_tracer

from .lower import (ExecStep, LoweringError, PermRound, _fold_jax,
                    _round_jax, _GUARD_REGISTRY, guard_schedule)


# ---------------------------------------------------------------------------
# Merge analysis
# ---------------------------------------------------------------------------
def _round_endpoints(rd: PermRound) -> tuple[set, set]:
    senders = {s for s, _ in rd.perm}
    receivers = {d for _, d in rd.perm}
    return senders, receivers


def rounds_link_disjoint(ra: PermRound, rb: PermRound) -> bool:
    """True when the two rounds occupy disjoint link sets on a
    single-switch axis: a device's up-link carries its send, its
    down-link its receive, so disjointness is 'no common sender and no
    common receiver'. Disjoint pairs coalesce (fully overlap, priced at
    max); shared pairs serialize their β/ε in the contended model."""
    sa, ra_ = _round_endpoints(ra)
    sb, rb_ = _round_endpoints(rb)
    return not (sa & sb) and not (ra_ & rb_)


def _unwrap(sched):
    """The raw CompiledSchedule under a (possibly) guarded schedule."""
    return getattr(sched, "inner", sched)


def _rs_steps(sched) -> list[ExecStep]:
    """The step stream the RS constituent contributes: its RS half plus
    the canonical reorder round."""
    return list(sched.rs) + ([sched.reorder]
                             if sched.reorder is not None else [])


def _ag_steps(sched) -> list[ExecStep]:
    """The step stream the AG constituent contributes: the unorder round
    plus its AG half."""
    return ([sched.unorder] if sched.unorder is not None else []) \
        + list(sched.ag)


@dataclass(frozen=True)
class MergeInfo:
    """Static analysis of one merge: how many round pairs interleave and
    how many coalesce (disjoint link sets). `coalesced_fraction` is what
    the trace span and the occupancy gauge report — a low fraction means
    the contended price sits near serial and the planner should usually
    reject the merge."""
    n: int
    steps_rs: int
    steps_ag: int
    round_pairs: int
    coalesced: int

    @property
    def serialized(self) -> int:
        return self.round_pairs - self.coalesced

    @property
    def coalesced_fraction(self) -> float:
        return self.coalesced / self.round_pairs if self.round_pairs else 1.0


def plan_merge(rs_sched, ag_sched) -> MergeInfo:
    """Validate that `rs_sched`'s RS half can merge with `ag_sched`'s AG
    half and analyze the interleaving. Raises LoweringError on any
    contract violation (the dataflow contract of core.lower carries
    over: both constituents were validated by `lower_plan`; the merge
    only adds cross-schedule requirements)."""
    a, b = _unwrap(rs_sched), _unwrap(ag_sched)
    if a.n != b.n:
        raise LoweringError(
            f"cannot merge schedules over different axis sizes: "
            f"{a.plan_name!r} has n={a.n}, {b.plan_name!r} n={b.n}")
    if a.family not in ("allreduce", "reduce_scatter"):
        raise LoweringError(
            f"merge RS side must be allreduce/reduce_scatter family; "
            f"{a.plan_name!r} is {a.family!r}")
    if b.family not in ("allreduce", "allgather"):
        raise LoweringError(
            f"merge AG side must be allreduce/allgather family; "
            f"{b.plan_name!r} is {b.family!r}")
    for s, what in ((a, "RS"), (b, "AG")):
        if s.blocks_per_shard is None:
            raise LoweringError(
                f"merge {what} side {s.plan_name!r} has no canonical "
                f"shard layout (num_blocks % n != 0)")
    sa, sb = _rs_steps(a), _ag_steps(b)
    pairs = coalesced = 0
    for i in range(min(len(sa), len(sb))):
        ra, rb = sa[i].rounds, sb[i].rounds
        for j in range(min(len(ra), len(rb))):
            pairs += 1
            if rounds_link_disjoint(ra[j], rb[j]):
                coalesced += 1
    return MergeInfo(n=a.n, steps_rs=len(sa), steps_ag=len(sb),
                     round_pairs=pairs, coalesced=coalesced)


# ---------------------------------------------------------------------------
# Merged schedule
# ---------------------------------------------------------------------------
class MergedSchedule:
    """One RS half and one AG half interleaved into a single issuance.

    `rs_ag(x, shard, axis)` runs `rs_sched.reduce_scatter(x)` and
    `ag_sched.all_gather(shard)` as ONE interleaved round stream and
    returns `(rs_shard, ag_full)`. Both constituents may be guarded
    and/or wire-bound; the merged path interleaves at round granularity
    when both run full precision, and at step granularity otherwise
    (each step then runs through the constituent's own compressed
    `_run_steps_wire`, so quantized payloads and scale plumbing are
    untouched).

    Guard contract (duck-typed against `GuardedSchedule` so
    `reprobe_guards` re-arms it): a failed merged launch demotes the
    wrapper — subsequent launches run the constituents SEQUENTIALLY
    through their own guard ladders, preserving every lower rung.
    """

    def __init__(self, rs_sched, ag_sched, *, telemetry=None, policy=None):
        self.info = plan_merge(rs_sched, ag_sched)
        # keep the guarded wrappers as the sequential fallback rung; the
        # raw schedules drive the merged path
        self.rs_guard = guard_schedule(rs_sched, telemetry=telemetry,
                                       policy=policy)
        self.ag_guard = guard_schedule(ag_sched, telemetry=telemetry,
                                       policy=policy)
        self.rs_inner = _unwrap(rs_sched)
        self.ag_inner = _unwrap(ag_sched)
        self.telemetry = telemetry
        self.plan_name = (f"merge({self.rs_inner.plan_name}"
                          f"+{self.ag_inner.plan_name})")
        self.n = self.rs_inner.n
        self._demoted = False
        self._wire_demoted = False      # reprobe_guards duck-type
        self.stats = {"launches": 0, "fallbacks": 0,
                      "demoted_launches": 0, "reprobes": 0}
        _GUARD_REGISTRY.add(self)

    # -- guard duck-type ----------------------------------------------------
    @property
    def demoted(self) -> bool:
        return self._demoted

    def reset_guard(self) -> None:
        self._demoted = False
        self._wire_demoted = False

    def describe(self) -> str:
        i = self.info
        return (f"{self.plan_name}: n={self.n} steps={i.steps_rs}"
                f"|{i.steps_ag} round_pairs={i.round_pairs} "
                f"coalesced={i.coalesced} "
                f"({i.coalesced_fraction:.0%} disjoint)")

    def _remeasure(self, reason: str, info: dict) -> None:
        tele = self.telemetry
        if tele is None:
            from repro.runtime.telemetry import peek_default_telemetry
            tele = peek_default_telemetry()
        if tele is not None:
            tele.remeasure(reason, info)

    # -- sequential fallback rung -------------------------------------------
    def _sequential(self, x, shard, axis_name: str,
                    fused_reduce: Callable | None):
        new_shard = self.rs_guard.reduce_scatter(
            x, axis_name, fused_reduce=fused_reduce)
        full = self.ag_guard.all_gather(shard, axis_name)
        return new_shard, full

    # -- merged execution ----------------------------------------------------
    def _merged(self, x, shard, axis_name: str,
                fused_reduce: Callable | None):
        import jax.numpy as jnp
        from jax import lax

        a, b = self.rs_inner, self.ag_inner
        idx = lax.axis_index(axis_name)

        # RS-side buffer prep (mirrors CompiledSchedule.reduce_scatter)
        flat = x.reshape(-1)
        pad_a = (-flat.size) % a.num_blocks
        if pad_a:
            flat = jnp.concatenate([flat, jnp.zeros((pad_a,), flat.dtype)])
        buf_a = flat.reshape(a.num_blocks, -1)

        # AG-side buffer prep (mirrors CompiledSchedule.all_gather)
        kb = b.blocks_per_shard
        sflat = shard.reshape(-1)
        buf_b = jnp.zeros((b.num_blocks, sflat.size // kb), sflat.dtype)
        buf_b = lax.dynamic_update_slice_in_dim(
            buf_b, sflat.reshape(kb, -1), idx * kb, axis=0)

        steps_a, steps_b = _rs_steps(a), _ag_steps(b)
        # the reorder step (last of steps_a) runs foldless-movement
        # semantics: fused_reduce never applies there in the sequential
        # entry points, so mirror that boundary exactly
        n_rs = len(a.rs)
        info = self.info
        with default_tracer().span(
                "overlap/rs_ag", plan=self.plan_name, n=self.n,
                round_pairs=info.round_pairs, coalesced=info.coalesced,
                serialized=info.serialized):
            if a.wire is None and b.wire is None:
                chunk_a, chunk_b = buf_a.shape[1], buf_b.shape[1]
                zero_a = jnp.zeros((chunk_a,), buf_a.dtype)
                zero_b = jnp.zeros((chunk_b,), buf_b.dtype)
                for i in range(max(len(steps_a), len(steps_b))):
                    sa = steps_a[i] if i < len(steps_a) else None
                    sb = steps_b[i] if i < len(steps_b) else None
                    with default_tracer().span(
                            "overlap/step", step=i,
                            rs_rounds=len(sa.rounds) if sa else 0,
                            ag_rounds=len(sb.rounds) if sb else 0):
                        stage_a = jnp.zeros(
                            (max(sa.n_slots, 1), chunk_a),
                            buf_a.dtype) if sa is not None else None
                        stage_b = jnp.zeros(
                            (max(sb.n_slots, 1), chunk_b),
                            buf_b.dtype) if sb is not None else None
                        ra = sa.rounds if sa is not None else []
                        rb = sb.rounds if sb is not None else []
                        for j in range(max(len(ra), len(rb))):
                            if j < len(ra):
                                stage_a = _round_jax(
                                    ra[j], buf_a, stage_a, idx, zero_a,
                                    axis_name, j)
                            if j < len(rb):
                                stage_b = _round_jax(
                                    rb[j], buf_b, stage_b, idx, zero_b,
                                    axis_name, j)
                        if sa is not None:
                            fr = fused_reduce if i < n_rs else None
                            for fi, fd in enumerate(sa.folds):
                                buf_a = _fold_jax(fd, buf_a, stage_a,
                                                  idx, zero_a, fr, fi)
                        if sb is not None:
                            for fi, fd in enumerate(sb.folds):
                                buf_b = _fold_jax(fd, buf_b, stage_b,
                                                  idx, zero_b, None, fi)
            else:
                # compressed constituent(s): interleave at step
                # granularity — each step keeps its own wire machinery
                for i in range(max(len(steps_a), len(steps_b))):
                    if i < len(steps_a):
                        fr = fused_reduce if i < n_rs else None
                        buf_a = a._run_steps([steps_a[i]], buf_a,
                                             axis_name, fr, phase="rs")
                    if i < len(steps_b):
                        buf_b = b._run_steps([steps_b[i]], buf_b,
                                             axis_name, None, phase="ag")

        ka = a.blocks_per_shard
        new_shard = lax.dynamic_slice_in_dim(
            buf_a, idx * ka, ka, axis=0).reshape(-1)
        return new_shard, buf_b.reshape(-1)

    def rs_ag(self, x, shard, axis_name: str, *,
              fused_reduce: Callable | None = None):
        """Merged launch: RS of `x` interleaved with AG of `shard`.
        Returns `(rs_shard, ag_full)` — identical values to running the
        constituents sequentially."""
        m = default_metrics()
        self.stats["launches"] += 1
        m.counter("overlap_merged_launches_total",
                  "merged RS+AG launches through the overlap scheduler"
                  ).inc()
        if self._demoted:
            self.stats["demoted_launches"] += 1
            m.counter("overlap_merged_demoted_launches_total",
                      "merged launches served sequentially after demotion"
                      ).inc()
            return self._sequential(x, shard, axis_name, fused_reduce)
        try:
            from repro.runtime.faults import active_injector
            inj = active_injector()
            if inj is not None:
                inj.check_launch(f"{self.plan_name}/rs_ag")
            return self._merged(x, shard, axis_name, fused_reduce)
        except Exception as e:            # noqa: BLE001 — ladder rung
            self.stats["fallbacks"] += 1
            self._demoted = True
            m.counter("overlap_merged_fallbacks_total",
                      "merged launches demoted to sequential execution"
                      ).inc()
            default_tracer().instant("overlap/fallback",
                                     plan=self.plan_name, error=repr(e))
            self._remeasure("overlap_fallback",
                            {"plan": self.plan_name, "error": repr(e)})
            return self._sequential(x, shard, axis_name, fused_reduce)

    # -- numpy mirror (reference; tests) -------------------------------------
    def run_numpy_pair(self, X: np.ndarray, shards: np.ndarray,
                       order: Sequence[str] | None = None
                       ) -> tuple[np.ndarray, np.ndarray]:
        """Numpy mirror of `rs_ag` with a controllable interleaving.

        `X` is the (n, size) per-device RS contribution matrix; `shards`
        the (n, shard_size) per-device AG input shards. `order` is a
        token stream over {'a', 'b'} consumed step-wise (default: strict
        alternation, the merged executor's order) — any order preserving
        each constituent's internal sequence must produce identical
        results, which is exactly what the hypothesis sweep asserts.
        Returns `(rs_shards (n, k·chunk), ag_full (n, num_blocks·chunk))`
        at full precision (like `CompiledSchedule.run_numpy`)."""
        a, b = self.rs_inner, self.ag_inner
        n = self.n
        X = np.asarray(X)
        if X.shape[0] != n or np.asarray(shards).shape[0] != n:
            raise LoweringError(f"expected {n} device rows")
        size = X.shape[1]
        pad_a = (-size) % a.num_blocks
        if pad_a:
            X = np.concatenate([X, np.zeros((n, pad_a), X.dtype)], axis=1)
        buf_a = X.reshape(n, a.num_blocks, -1).copy()

        shards = np.asarray(shards)
        kb = b.blocks_per_shard
        chunk_b = shards.shape[1] // kb
        buf_b = np.zeros((n, b.num_blocks, chunk_b), shards.dtype)
        for d in range(n):
            buf_b[d, d * kb:(d + 1) * kb] = shards[d].reshape(kb, -1)

        steps_a, steps_b = _rs_steps(a), _ag_steps(b)
        if order is None:
            order = []
            for i in range(max(len(steps_a), len(steps_b))):
                if i < len(steps_a):
                    order.append("a")
                if i < len(steps_b):
                    order.append("b")
        toks = list(order)
        if (toks.count("a") != len(steps_a)
                or toks.count("b") != len(steps_b)
                or len(toks) != len(steps_a) + len(steps_b)):
            raise LoweringError(
                f"interleaving order needs exactly {len(steps_a)} 'a' and "
                f"{len(steps_b)} 'b' tokens, got {toks!r}")
        ia = ib = 0
        for tok in toks:
            if tok == "a":
                buf_a = a._run_steps_numpy([steps_a[ia]], buf_a,
                                           phase="rs")
                ia += 1
            else:
                buf_b = b._run_steps_numpy([steps_b[ib]], buf_b,
                                           phase="ag")
                ib += 1

        ka = a.blocks_per_shard
        rs_out = np.stack([buf_a[d, d * ka:(d + 1) * ka].reshape(-1)
                           for d in range(n)])
        return rs_out, buf_b.reshape(n, -1)


def merge_schedules(rs_sched, ag_sched, *, telemetry=None,
                    policy=None) -> MergedSchedule:
    """Build (and validate) a MergedSchedule. Memoized per (rs, ag)
    schedule-object pair on the RS schedule, mirroring `guard_schedule`'s
    per-object memo, so demotion state survives re-resolves of the same
    cached schedules."""
    inner = _unwrap(rs_sched)
    memo = getattr(inner, "_merge_wrappers", None)
    if memo is None:
        memo = {}
        try:
            inner._merge_wrappers = memo
        except (AttributeError, TypeError):
            return MergedSchedule(rs_sched, ag_sched, telemetry=telemetry,
                                  policy=policy)
    key = id(_unwrap(ag_sched))
    ms = memo.get(key)
    if ms is None:
        ms = MergedSchedule(rs_sched, ag_sched, telemetry=telemetry,
                            policy=policy)
        memo[key] = ms
    return ms


# ---------------------------------------------------------------------------
# Occupancy summary (satellite of DESIGN.md §15: the gauge + span
# attributes that make Chrome traces show which links serialized)
# ---------------------------------------------------------------------------
def occupancy_summary(topo, step_a, step_b, unit_bytes: int = 4) -> dict:
    """Merged per-link occupancy of two concurrent Steps: how many links
    each side touches, how many they share, and the busiest link's
    combined units — the quantities the planner emits as the
    `overlap_*` gauges and `overlap/priced` span attributes."""
    from .cost_model import link_occupancy
    oa = link_occupancy(topo, step_a, unit_bytes)
    ob = link_occupancy(topo, step_b, unit_bytes)
    shared = set(oa.link_units) & set(ob.link_units)
    merged = oa.merge(ob)
    busiest, units = -1, 0.0
    for lid, u in merged.link_units.items():
        if u > units:
            busiest, units = int(lid), float(u)
    return {"links_rs": len(oa.link_units), "links_ag": len(ob.link_units),
            "links_shared": len(shared), "busiest_link": busiest,
            "busiest_link_units": units}
