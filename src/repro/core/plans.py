"""AllReduce plan IR + builders for the classic plan types (paper §2.1).

A Plan is a sequence of synchronized Steps. Each Step contains point-to-point
Transfers (server→server, some number of data *blocks*) and ReduceOps (a
server folds `fan_in` blocks into one). Sizes are in data units ("floats" in
the paper); the cost model/simulator multiplies by unit size.

Block identity (DESIGN.md §8): an *executable* plan additionally records,
per Transfer and per ReduceOp, WHICH blocks move or fold. The AllReduce
input vector of `size` units is split into `Plan.num_blocks` equal blocks;
`Transfer.blocks` names the block shards whose current partial sum moves,
`ReduceOp.blocks` the shards being folded. The cost engines ignore these
fields entirely (pricing is byte-identical with or without them); they
exist so `core.lower` can compile the plan into an executable shard_map
schedule and structurally validate it (every server contribution of every
block reduced exactly once, all-gather completeness).

The IR is consumed by:
  * core.cost_model.evaluate_plan  — GenModel closed-form style accounting
  * core.simulator.simulate        — link-aware flow-level simulation
  * core.lower.lower_plan          — compilation to executable schedules
  * core.collectives               — mapping onto JAX lax collectives
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

# Collective families a Plan can describe (DESIGN.md §14). The cost
# engines are family-agnostic — they price whatever steps the plan
# contains — but `core.lower` validates and compiles each family against
# its own dataflow contract:
#   allreduce      — every contribution of every block reduced once, then
#                    every server holds every block (the PR-3 contract);
#   reduce_scatter — the RS half alone: each block fully reduced at ≥1
#                    holder; output is the canonical shard per server;
#   allgather      — movement only: initial holders are inferred from the
#                    steps, and every server must end holding every block;
#   all_to_all     — movement only: block b of src's operand row for dst
#                    lands at dst as src's row (lax.all_to_all semantics);
#   p2p            — movement only: each (src, dst) edge replaces dst's
#                    buffer with src's payload (pipeline boundary shift).
FAMILIES = ("allreduce", "reduce_scatter", "allgather", "all_to_all", "p2p")


@dataclass(frozen=True)
class Transfer:
    src: int
    dst: int
    size: float  # data units moved (e.g. floats)
    # Block identity: which shards' partials move (None = unannotated IR;
    # priced identically, but not lowerable to an executable schedule).
    # size == len(blocks) * (plan.size / plan.num_blocks) when annotated.
    blocks: tuple[int, ...] | None = None


@dataclass(frozen=True)
class ReduceOp:
    server: int
    fan_in: int   # number of operand blocks folded into one output block
    size: float   # size of ONE block (= output size)
    # Block identity: which shards this fold produces (None = unannotated).
    # size == len(blocks) * (plan.size / plan.num_blocks) when annotated.
    blocks: tuple[int, ...] | None = None

    @property
    def adds(self) -> float:
        """γ-term ops: (fan_in - 1) * size."""
        return (self.fan_in - 1) * self.size

    @property
    def mem_ops(self) -> float:
        """δ-term ops: fan_in reads + 1 write per element (paper §3.1)."""
        return (self.fan_in + 1) * self.size


@dataclass(frozen=True)
class QuantReduceOp(ReduceOp):
    """A fold on a compressed wire (`cost_model.compressed_plan`): the
    quant/dequant passes ride as extra γ adds and δ mem_ops on top of the
    fold's own (fan_in − 1)·S / (fan_in + 1)·S accounting, so every
    pricer charges compression through the ops it already reads."""
    extra_adds: float = 0.0
    extra_mem_ops: float = 0.0

    @property
    def adds(self) -> float:
        return (self.fan_in - 1) * self.size + self.extra_adds

    @property
    def mem_ops(self) -> float:
        return (self.fan_in + 1) * self.size + self.extra_mem_ops


@dataclass
class Step:
    """One synchronized round.

    Mutation rules: plan builders append to `transfers`/`reduces` while
    constructing a step and must finish before the step is priced — the
    per-destination aggregates below are cached on first use. The cache is
    keyed on `len(transfers)`, so the common builder pattern (append, then
    simulate, then append more — e.g. `_merge_concurrent` extending a step)
    invalidates naturally; *replacing* a transfer without changing the list
    length is not supported (call `invalidate_caches()` by hand if you must).
    Callers must treat the returned dicts as read-only.
    """
    transfers: list[Transfer] = field(default_factory=list)
    reduces: list[ReduceOp] = field(default_factory=list)
    _dst_cache: tuple | None = field(default=None, repr=False, compare=False)

    def invalidate_caches(self) -> None:
        self._dst_cache = None

    def _by_dst(self) -> tuple[dict[int, float], dict[int, int]]:
        cache = self._dst_cache
        if cache is not None and cache[0] == len(self.transfers):
            return cache[1], cache[2]
        recv: dict[int, float] = {}
        fan: dict[int, int] = {}
        seen = set()
        for t in self.transfers:
            recv[t.dst] = recv.get(t.dst, 0.0) + t.size
            if (t.src, t.dst) not in seen:
                seen.add((t.src, t.dst))
                fan[t.dst] = fan.get(t.dst, 0) + 1
        self._dst_cache = (len(self.transfers), recv, fan)
        return recv, fan

    def recv_bytes_by_dst(self) -> dict[int, float]:
        return self._by_dst()[0]

    def fan_in_by_dst(self) -> dict[int, int]:
        return self._by_dst()[1]


@dataclass
class Plan:
    name: str
    n: int                 # number of participating servers
    size: float            # S: total data units per server
    steps: list[Step] = field(default_factory=list)
    servers: list[int] | None = None  # actual server ids (default 0..n-1)
    # Block granularity of the annotated IR: the size-unit vector is split
    # into num_blocks equal shards, indexed 0..num_blocks-1. None marks a
    # legacy/unannotated plan (prices fine, cannot be lowered).
    num_blocks: int | None = None
    # Which collective this plan computes (one of FAMILIES). Pricing walks
    # the steps either way; lowering and the execution entry points key off
    # this to pick the right validation contract and runtime surface.
    family: str = "allreduce"

    def ids(self) -> list[int]:
        return self.servers if self.servers is not None else list(range(self.n))

    # -- invariants (used by property tests) --------------------------------
    def total_traffic_per_server(self) -> dict[int, float]:
        out = {i: 0.0 for i in self.ids()}
        for st in self.steps:
            for t in st.transfers:
                out[t.src] = out.get(t.src, 0.0) + t.size
        return out

    def total_mem_ops(self) -> float:
        return sum(r.mem_ops for st in self.steps for r in st.reduces)

    def mem_ops_per_server(self) -> dict[int, float]:
        out: dict[int, float] = {}
        for st in self.steps:
            for r in st.reduces:
                out[r.server] = out.get(r.server, 0.0) + r.mem_ops
        return out

    def max_mem_ops_per_server(self) -> float:
        """The parallel memory-access cost (Theorem 1 compares this: every
        server reduces its own block concurrently)."""
        per = self.mem_ops_per_server()
        return max(per.values()) if per else 0.0

    def total_adds(self) -> float:
        return sum(r.adds for st in self.steps for r in st.reduces)

    def max_fan_in(self) -> int:
        """Max communication fan-in w (paper counts the receiver's own
        block: w = #senders + 1)."""
        fi = [0]
        for st in self.steps:
            fi.extend(v + 1 for v in st.fan_in_by_dst().values())
        return max(fi)


# ---------------------------------------------------------------------------
# Builders — single-switch, N servers, S data units each.
# ---------------------------------------------------------------------------
def ring(n: int, size: float, servers: list[int] | None = None) -> Plan:
    """Ring AllReduce: 2(N-1) steps of S/N-sized neighbor exchanges.

    Block schedule (the canonical ring walk): at ReduceScatter step s,
    server i forwards its partial of block (i - s) mod n to i+1, so after
    N-1 folds server j owns block (j + 1) mod n; the AllGather phase walks
    the finished blocks the same direction."""
    ids = servers if servers is not None else list(range(n))
    blk = size / n
    p = Plan("ring", n, size, servers=servers, num_blocks=n)
    # ReduceScatter phase.
    for s in range(n - 1):
        st = Step()
        for i in range(n):
            b = (i - s) % n
            st.transfers.append(Transfer(ids[i], ids[(i + 1) % n], blk,
                                         blocks=(b,)))
            st.reduces.append(ReduceOp(ids[(i + 1) % n], 2, blk,
                                       blocks=(b,)))
        p.steps.append(st)
    # AllGather phase.
    for a in range(n - 1):
        st = Step()
        for i in range(n):
            b = (i + 1 - a) % n
            st.transfers.append(Transfer(ids[i], ids[(i + 1) % n], blk,
                                         blocks=(b,)))
        p.steps.append(st)
    return p


def cps(n: int, size: float, servers: list[int] | None = None) -> Plan:
    """Co-located PS: 1 full-mesh ReduceScatter step (fan-in N) + 1 AllGather."""
    ids = servers if servers is not None else list(range(n))
    blk = size / n
    p = Plan("cps", n, size, servers=servers, num_blocks=n)
    rs = Step()
    for i in range(n):
        for j in range(n):
            if i != j:
                # server i ships its contribution to block j's owner
                rs.transfers.append(Transfer(ids[i], ids[j], blk,
                                             blocks=(j,)))
        rs.reduces.append(ReduceOp(ids[i], n, blk, blocks=(i,)))
    p.steps.append(rs)
    ag = Step()
    for i in range(n):
        for j in range(n):
            if i != j:
                ag.transfers.append(Transfer(ids[i], ids[j], blk,
                                             blocks=(i,)))
    p.steps.append(ag)
    return p


def reduce_broadcast(n: int, size: float, servers: list[int] | None = None) -> Plan:
    """Naive PS: everyone → root (reduce), root → everyone (broadcast)."""
    ids = servers if servers is not None else list(range(n))
    root = ids[0]
    # The root folds whole vectors — a single block of all `size` units.
    p = Plan("reduce_broadcast", n, size, servers=servers, num_blocks=1)
    rs = Step()
    for i in ids[1:]:
        rs.transfers.append(Transfer(i, root, size, blocks=(0,)))
    rs.reduces.append(ReduceOp(root, n, size, blocks=(0,)))
    p.steps.append(rs)
    bc = Step()
    for i in ids[1:]:
        bc.transfers.append(Transfer(root, i, size, blocks=(0,)))
    p.steps.append(bc)
    return p


def rhd(n: int, size: float, servers: list[int] | None = None) -> Plan:
    """Recursive Halving & Doubling. Non-power-of-two handled with the
    standard fold-in/fold-out patch (the χ(N) extra steps of Table 1).

    Blocks are sized at the pow2 core's final-shard granularity
    (num_blocks = pow2): at halving step j, core server i holds the range
    of 2·dist blocks selected by its high bits and sends the half NOT
    matching bit (i//dist)%2 to peer i^dist, ending with server i owning
    block i; doubling mirrors the ranges back."""
    ids = servers if servers is not None else list(range(n))
    pow2 = 1 << (n.bit_length() - 1)
    extra = n - pow2  # servers folded into partners
    p = Plan("rhd", n, size, servers=servers, num_blocks=pow2)
    all_blocks = tuple(range(pow2))

    if extra:
        st = Step()
        for e in range(extra):
            # server pow2+e sends everything to server e.
            st.transfers.append(Transfer(ids[pow2 + e], ids[e], size,
                                         blocks=all_blocks))
            st.reduces.append(ReduceOp(ids[e], 2, size, blocks=all_blocks))
        p.steps.append(st)

    core = ids[:pow2]
    # Halving (ReduceScatter): step j exchanges size/2^(j+1).
    for j in range(int(math.log2(pow2))):
        dist = pow2 >> (j + 1)
        sz = size / (1 << (j + 1))
        st = Step()
        for i in range(pow2):
            peer = i ^ dist
            bit = (i // dist) % 2
            base = i & ~(2 * dist - 1)
            sent = tuple(range(base + (1 - bit) * dist,
                               base + (1 - bit) * dist + dist))
            st.transfers.append(Transfer(core[i], core[peer], sz,
                                         blocks=sent))
            st.reduces.append(ReduceOp(core[peer], 2, sz, blocks=sent))
        p.steps.append(st)
    # Doubling (AllGather).
    for j in reversed(range(int(math.log2(pow2)))):
        dist = pow2 >> (j + 1)
        sz = size / (1 << (j + 1))
        st = Step()
        for i in range(pow2):
            peer = i ^ dist
            base = i & ~(dist - 1)
            held = tuple(range(base, base + dist))
            st.transfers.append(Transfer(core[i], core[peer], sz,
                                         blocks=held))
        p.steps.append(st)

    if extra:
        st = Step()
        for e in range(extra):
            st.transfers.append(Transfer(ids[e], ids[pow2 + e], size,
                                         blocks=all_blocks))
        p.steps.append(st)
    return p


def hcps(factors: list[int], size: float,
         servers: list[int] | None = None) -> Plan:
    """m-step Hierarchical Co-located PS with orthogonal groupings
    (paper Figure 5). factors = [f_0, ..., f_{m-1}], N = prod(factors).

    Grouping for step i: servers whose mixed-radix digits differ only in
    digit i form a group of size f_i. Each step is a CPS ReduceScatter on
    the surviving block shard; AllGather mirrors in reverse.
    """
    n = 1
    for f in factors:
        n *= f
    ids = servers if servers is not None else list(range(n))
    p = Plan("hcps_" + "x".join(map(str, factors)), n, size, servers=servers,
             num_blocks=n)

    def digits(x: int) -> list[int]:
        d = []
        for f in factors:
            d.append(x % f)
            x //= f
        return d

    def groups(step: int) -> list[list[int]]:
        """Indices grouped by all digits except digit `step`. Members are
        listed in index order == increasing digit-`step` order."""
        by_key: dict[tuple, list[int]] = {}
        for i in range(n):
            d = digits(i)
            key = tuple(d[:step] + d[step + 1:])
            by_key.setdefault(key, []).append(i)
        return list(by_key.values())

    # Block bookkeeping: every server starts holding the full block range;
    # at RS stage si each group member keeps the sub-range indexed by its
    # own digit and ships sub-range j to the member with digit j.
    rng: dict[int, tuple[int, int]] = {i: (0, n) for i in range(n)}

    # ReduceScatter stages: after stage i each member of a group owns 1/f_i
    # of the shard it held before the stage.
    shard = size
    for si, f in enumerate(factors):
        st = Step()
        blk = shard / f
        for g in groups(si):
            assert len(g) == f
            start, length = rng[g[0]]       # shared across the group
            piece = length // f
            for ja, a in enumerate(g):
                for jb, b in enumerate(g):
                    if a != b:
                        sub = tuple(range(start + jb * piece,
                                          start + (jb + 1) * piece))
                        st.transfers.append(Transfer(ids[a], ids[b], blk,
                                                     blocks=sub))
            for ja, a in enumerate(g):
                own = tuple(range(start + ja * piece,
                                  start + (ja + 1) * piece))
                st.reduces.append(ReduceOp(ids[a], f, blk, blocks=own))
                rng[a] = (start + ja * piece, piece)
        p.steps.append(st)
        shard = blk

    # AllGather stages (reverse order, same groupings, no reduce).
    for si in reversed(range(len(factors))):
        f = factors[si]
        blk = shard
        st = Step()
        for g in groups(si):
            for a in g:
                sa, la = rng[a]
                sub = tuple(range(sa, sa + la))
                for b in g:
                    if a != b:
                        st.transfers.append(Transfer(ids[a], ids[b], blk,
                                                     blocks=sub))
            lo = min(rng[a][0] for a in g)
            length = sum(rng[a][1] for a in g)
            for a in g:
                rng[a] = (lo, length)
        p.steps.append(st)
        shard = shard * f
    return p


# ---------------------------------------------------------------------------
# Per-family builders (DESIGN.md §14). `size` follows each family's natural
# operand convention:
#   allgather_plan      — size = the FULL result vector (each server starts
#                         with its 1/n shard and ends with all of it);
#   reduce_scatter_plan — size = the full per-server input vector (each
#                         server ends with its reduced 1/n shard);
#   alltoall_plan       — size = the per-server operand (each server ships
#                         (n-1)/n of it and keeps its diagonal chunk);
#   p2p_plan            — size = the full buffer each edge moves.
# The evaluators need no changes: wire bytes, incast fan-in and memory
# passes fall out of the steps themselves (AG moves (n-1)/n of the result,
# AllToAll (n-1)/n of the operand, and neither folds anything).
# ---------------------------------------------------------------------------
def allgather_plan(n: int, size: float, servers: list[int] | None = None,
                   strategy: str = "ring") -> Plan:
    """Standalone AllGather: server i starts holding block i of the
    `size`-unit result; after the plan every server holds every block.

    strategy="ring": n-1 rounds of neighbor forwarding (block (i - a) mod n
    moves i → i+1 at round a — the AG half of the ring walk). "mesh": one
    full-mesh round (the CPS AG half: fan-in n-1, one α)."""
    ids = servers if servers is not None else list(range(n))
    blk = size / n
    p = Plan(f"allgather_{strategy}", n, size, servers=servers,
             num_blocks=n, family="allgather")
    if n == 1:
        return p
    if strategy == "mesh":
        st = Step()
        for i in range(n):
            for j in range(n):
                if i != j:
                    st.transfers.append(Transfer(ids[i], ids[j], blk,
                                                 blocks=(i,)))
        p.steps.append(st)
    elif strategy == "ring":
        for a in range(n - 1):
            st = Step()
            for i in range(n):
                b = (i - a) % n
                st.transfers.append(Transfer(ids[i], ids[(i + 1) % n], blk,
                                             blocks=(b,)))
            p.steps.append(st)
    else:
        raise ValueError(f"unknown allgather strategy: {strategy!r}")
    return p


def reduce_scatter_plan(n: int, size: float,
                        servers: list[int] | None = None,
                        strategy: str = "ring") -> Plan:
    """Standalone ReduceScatter: every server contributes a `size`-unit
    vector; server i ends owning the fully-reduced block i (canonical
    shard — `core.lower` appends the reorder movement when the walk's
    natural owner differs).

    strategy="ring": the n-1 fold rounds of the ring walk. "mesh": one
    full-mesh round (the CPS RS half, fan-in n)."""
    ids = servers if servers is not None else list(range(n))
    blk = size / n
    p = Plan(f"reduce_scatter_{strategy}", n, size, servers=servers,
             num_blocks=n, family="reduce_scatter")
    if n == 1:
        return p
    if strategy == "mesh":
        st = Step()
        for i in range(n):
            for j in range(n):
                if i != j:
                    st.transfers.append(Transfer(ids[i], ids[j], blk,
                                                 blocks=(j,)))
            st.reduces.append(ReduceOp(ids[i], n, blk, blocks=(i,)))
        p.steps.append(st)
    elif strategy == "ring":
        for s in range(n - 1):
            st = Step()
            for i in range(n):
                b = (i - s) % n
                st.transfers.append(Transfer(ids[i], ids[(i + 1) % n], blk,
                                             blocks=(b,)))
                st.reduces.append(ReduceOp(ids[(i + 1) % n], 2, blk,
                                           blocks=(b,)))
            p.steps.append(st)
    else:
        raise ValueError(f"unknown reduce_scatter strategy: {strategy!r}")
    return p


def alltoall_plan(n: int, size: float,
                  servers: list[int] | None = None) -> Plan:
    """Single-switch AllToAll: each server's `size`-unit operand is split
    into n destination chunks (block j = the chunk bound for server j);
    one full-mesh round ships the n-1 off-diagonal chunks — (n-1)/n·size
    wire units per server, fan-in n-1, zero reduces. Matches
    `lax.all_to_all(x.reshape(n, -1), axis, 0, 0)` up to the row→chunk
    transpose the lowered schedule performs."""
    ids = servers if servers is not None else list(range(n))
    blk = size / n
    p = Plan("alltoall", n, size, servers=servers, num_blocks=n,
             family="all_to_all")
    if n == 1:
        return p
    st = Step()
    for i in range(n):
        for j in range(n):
            if i != j:
                st.transfers.append(Transfer(ids[i], ids[j], blk,
                                             blocks=(j,)))
    p.steps.append(st)
    return p


def p2p_plan(n: int, size: float, servers: list[int] | None = None,
             pairs: list[tuple[int, int]] | None = None) -> Plan:
    """Point-to-point exchange: each (src, dst) pair moves the full
    `size`-unit buffer in one round — the pipeline-parallel boundary
    shift. Default pairs: the ring shift i → (i+1) mod n. Indices in
    `pairs` are positions (0..n-1), mapped through `servers`."""
    ids = servers if servers is not None else list(range(n))
    if pairs is None:
        pairs = [(i, (i + 1) % n) for i in range(n)] if n > 1 else []
    p = Plan("p2p", n, size, servers=servers, num_blocks=1, family="p2p")
    if not pairs:
        return p
    st = Step()
    for s, d in pairs:
        if s == d:
            raise ValueError(f"p2p pair with src == dst: {s}")
        st.transfers.append(Transfer(ids[s], ids[d], size, blocks=(0,)))
    p.steps.append(st)
    return p


def family_halves(plan: Plan) -> tuple[Plan, Plan]:
    """Kolmakov–Zhang decomposition (arXiv 2004.09362): split a
    block-annotated AllReduce plan at its last folding step into the
    standalone ReduceScatter-family prefix and the AllGather-family
    suffix. The AG half starts from the RS half's ownership layout —
    `core.lower` infers each block's initial holder from the steps, so
    any GenTree/builder AllReduce yields a lowerable RS and AG plan for
    free. Steps are shared by reference (treat them as read-only)."""
    if plan.family != "allreduce":
        raise ValueError(f"family_halves needs an allreduce plan, "
                         f"got family={plan.family!r}")
    folds = [i for i, st in enumerate(plan.steps) if st.reduces]
    if not folds:
        raise ValueError(f"plan {plan.name} has no reduces — cannot split")
    cut = folds[-1] + 1
    rs = Plan(plan.name + ":rs", plan.n, plan.size, steps=plan.steps[:cut],
              servers=plan.servers, num_blocks=plan.num_blocks,
              family="reduce_scatter")
    ag = Plan(plan.name + ":ag", plan.n, plan.size, steps=plan.steps[cut:],
              servers=plan.servers, num_blocks=plan.num_blocks,
              family="allgather")
    return rs, ag


def factorizations(n: int, max_factor: int | None = None,
                   max_steps: int = 3) -> list[list[int]]:
    """All ordered factorizations of n into 2..max_steps factors ≥2
    (optionally capped per-factor). Used by GenTree's plan-type search."""
    out: list[list[int]] = []

    def rec(rem: int, cur: list[int]):
        if len(cur) >= 2 and rem == 1:
            out.append(list(cur))
            return
        if len(cur) >= max_steps and rem != 1:
            return
        if rem == 1:
            return
        f = 2
        while f <= rem:
            if rem % f == 0 and (max_factor is None or f <= max_factor):
                cur.append(f)
                rec(rem // f, cur)
                cur.pop()
            f += 1

    rec(n, [])
    return out
