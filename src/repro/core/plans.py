"""AllReduce plan IR + builders for the classic plan types (paper §2.1).

A Plan is a sequence of synchronized Steps. Each Step contains point-to-point
Transfers (server→server, some number of data *blocks*) and ReduceOps (a
server folds `fan_in` blocks into one). Sizes are in data units ("floats" in
the paper); the cost model/simulator multiplies by unit size.

The IR is consumed by:
  * core.cost_model.evaluate_plan  — GenModel closed-form style accounting
  * core.simulator.simulate        — link-aware flow-level simulation
  * core.collectives               — mapping onto JAX lax collectives
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Transfer:
    src: int
    dst: int
    size: float  # data units moved (e.g. floats)


@dataclass(frozen=True)
class ReduceOp:
    server: int
    fan_in: int   # number of operand blocks folded into one output block
    size: float   # size of ONE block (= output size)

    @property
    def adds(self) -> float:
        """γ-term ops: (fan_in - 1) * size."""
        return (self.fan_in - 1) * self.size

    @property
    def mem_ops(self) -> float:
        """δ-term ops: fan_in reads + 1 write per element (paper §3.1)."""
        return (self.fan_in + 1) * self.size


@dataclass
class Step:
    """One synchronized round.

    Mutation rules: plan builders append to `transfers`/`reduces` while
    constructing a step and must finish before the step is priced — the
    per-destination aggregates below are cached on first use. The cache is
    keyed on `len(transfers)`, so the common builder pattern (append, then
    simulate, then append more — e.g. `_merge_concurrent` extending a step)
    invalidates naturally; *replacing* a transfer without changing the list
    length is not supported (call `invalidate_caches()` by hand if you must).
    Callers must treat the returned dicts as read-only.
    """
    transfers: list[Transfer] = field(default_factory=list)
    reduces: list[ReduceOp] = field(default_factory=list)
    _dst_cache: tuple | None = field(default=None, repr=False, compare=False)

    def invalidate_caches(self) -> None:
        self._dst_cache = None

    def _by_dst(self) -> tuple[dict[int, float], dict[int, int]]:
        cache = self._dst_cache
        if cache is not None and cache[0] == len(self.transfers):
            return cache[1], cache[2]
        recv: dict[int, float] = {}
        fan: dict[int, int] = {}
        seen = set()
        for t in self.transfers:
            recv[t.dst] = recv.get(t.dst, 0.0) + t.size
            if (t.src, t.dst) not in seen:
                seen.add((t.src, t.dst))
                fan[t.dst] = fan.get(t.dst, 0) + 1
        self._dst_cache = (len(self.transfers), recv, fan)
        return recv, fan

    def recv_bytes_by_dst(self) -> dict[int, float]:
        return self._by_dst()[0]

    def fan_in_by_dst(self) -> dict[int, int]:
        return self._by_dst()[1]


@dataclass
class Plan:
    name: str
    n: int                 # number of participating servers
    size: float            # S: total data units per server
    steps: list[Step] = field(default_factory=list)
    servers: list[int] | None = None  # actual server ids (default 0..n-1)

    def ids(self) -> list[int]:
        return self.servers if self.servers is not None else list(range(self.n))

    # -- invariants (used by property tests) --------------------------------
    def total_traffic_per_server(self) -> dict[int, float]:
        out = {i: 0.0 for i in self.ids()}
        for st in self.steps:
            for t in st.transfers:
                out[t.src] = out.get(t.src, 0.0) + t.size
        return out

    def total_mem_ops(self) -> float:
        return sum(r.mem_ops for st in self.steps for r in st.reduces)

    def mem_ops_per_server(self) -> dict[int, float]:
        out: dict[int, float] = {}
        for st in self.steps:
            for r in st.reduces:
                out[r.server] = out.get(r.server, 0.0) + r.mem_ops
        return out

    def max_mem_ops_per_server(self) -> float:
        """The parallel memory-access cost (Theorem 1 compares this: every
        server reduces its own block concurrently)."""
        per = self.mem_ops_per_server()
        return max(per.values()) if per else 0.0

    def total_adds(self) -> float:
        return sum(r.adds for st in self.steps for r in st.reduces)

    def max_fan_in(self) -> int:
        """Max communication fan-in w (paper counts the receiver's own
        block: w = #senders + 1)."""
        fi = [0]
        for st in self.steps:
            fi.extend(v + 1 for v in st.fan_in_by_dst().values())
        return max(fi)


# ---------------------------------------------------------------------------
# Builders — single-switch, N servers, S data units each.
# ---------------------------------------------------------------------------
def ring(n: int, size: float, servers: list[int] | None = None) -> Plan:
    """Ring AllReduce: 2(N-1) steps of S/N-sized neighbor exchanges."""
    ids = servers if servers is not None else list(range(n))
    blk = size / n
    p = Plan("ring", n, size, servers=servers)
    # ReduceScatter phase.
    for _ in range(n - 1):
        st = Step()
        for i in range(n):
            st.transfers.append(Transfer(ids[i], ids[(i + 1) % n], blk))
            st.reduces.append(ReduceOp(ids[(i + 1) % n], 2, blk))
        p.steps.append(st)
    # AllGather phase.
    for _ in range(n - 1):
        st = Step()
        for i in range(n):
            st.transfers.append(Transfer(ids[i], ids[(i + 1) % n], blk))
        p.steps.append(st)
    return p


def cps(n: int, size: float, servers: list[int] | None = None) -> Plan:
    """Co-located PS: 1 full-mesh ReduceScatter step (fan-in N) + 1 AllGather."""
    ids = servers if servers is not None else list(range(n))
    blk = size / n
    p = Plan("cps", n, size, servers=servers)
    rs = Step()
    for i in range(n):
        for j in range(n):
            if i != j:
                rs.transfers.append(Transfer(ids[i], ids[j], blk))
        rs.reduces.append(ReduceOp(ids[i], n, blk))
    p.steps.append(rs)
    ag = Step()
    for i in range(n):
        for j in range(n):
            if i != j:
                ag.transfers.append(Transfer(ids[i], ids[j], blk))
    p.steps.append(ag)
    return p


def reduce_broadcast(n: int, size: float, servers: list[int] | None = None) -> Plan:
    """Naive PS: everyone → root (reduce), root → everyone (broadcast)."""
    ids = servers if servers is not None else list(range(n))
    root = ids[0]
    p = Plan("reduce_broadcast", n, size, servers=servers)
    rs = Step()
    for i in ids[1:]:
        rs.transfers.append(Transfer(i, root, size))
    rs.reduces.append(ReduceOp(root, n, size))
    p.steps.append(rs)
    bc = Step()
    for i in ids[1:]:
        bc.transfers.append(Transfer(root, i, size))
    p.steps.append(bc)
    return p


def rhd(n: int, size: float, servers: list[int] | None = None) -> Plan:
    """Recursive Halving & Doubling. Non-power-of-two handled with the
    standard fold-in/fold-out patch (the χ(N) extra steps of Table 1)."""
    ids = servers if servers is not None else list(range(n))
    p = Plan("rhd", n, size, servers=servers)
    pow2 = 1 << (n.bit_length() - 1)
    extra = n - pow2  # servers folded into partners

    if extra:
        st = Step()
        for e in range(extra):
            # server pow2+e sends everything to server e.
            st.transfers.append(Transfer(ids[pow2 + e], ids[e], size))
            st.reduces.append(ReduceOp(ids[e], 2, size))
        p.steps.append(st)

    core = ids[:pow2]
    # Halving (ReduceScatter): step j exchanges size/2^(j+1).
    for j in range(int(math.log2(pow2))):
        dist = pow2 >> (j + 1)
        sz = size / (1 << (j + 1))
        st = Step()
        for i in range(pow2):
            peer = i ^ dist
            st.transfers.append(Transfer(core[i], core[peer], sz))
            st.reduces.append(ReduceOp(core[peer], 2, sz))
        p.steps.append(st)
    # Doubling (AllGather).
    for j in reversed(range(int(math.log2(pow2)))):
        dist = pow2 >> (j + 1)
        sz = size / (1 << (j + 1))
        st = Step()
        for i in range(pow2):
            peer = i ^ dist
            st.transfers.append(Transfer(core[i], core[peer], sz))
        p.steps.append(st)

    if extra:
        st = Step()
        for e in range(extra):
            st.transfers.append(Transfer(ids[e], ids[pow2 + e], size))
        p.steps.append(st)
    return p


def hcps(factors: list[int], size: float,
         servers: list[int] | None = None) -> Plan:
    """m-step Hierarchical Co-located PS with orthogonal groupings
    (paper Figure 5). factors = [f_0, ..., f_{m-1}], N = prod(factors).

    Grouping for step i: servers whose mixed-radix digits differ only in
    digit i form a group of size f_i. Each step is a CPS ReduceScatter on
    the surviving block shard; AllGather mirrors in reverse.
    """
    n = 1
    for f in factors:
        n *= f
    ids = servers if servers is not None else list(range(n))
    p = Plan("hcps_" + "x".join(map(str, factors)), n, size, servers=servers)

    def digits(x: int) -> list[int]:
        d = []
        for f in factors:
            d.append(x % f)
            x //= f
        return d

    def groups(step: int) -> list[list[int]]:
        """Indices grouped by all digits except digit `step`."""
        by_key: dict[tuple, list[int]] = {}
        for i in range(n):
            d = digits(i)
            key = tuple(d[:step] + d[step + 1:])
            by_key.setdefault(key, []).append(i)
        return list(by_key.values())

    # ReduceScatter stages: after stage i each member of a group owns 1/f_i
    # of the shard it held before the stage.
    shard = size
    for si, f in enumerate(factors):
        st = Step()
        blk = shard / f
        for g in groups(si):
            assert len(g) == f
            for a in g:
                for b in g:
                    if a != b:
                        st.transfers.append(Transfer(ids[a], ids[b], blk))
            for a in g:
                st.reduces.append(ReduceOp(ids[a], f, blk))
        p.steps.append(st)
        shard = blk

    # AllGather stages (reverse order, same groupings, no reduce).
    for si in reversed(range(len(factors))):
        f = factors[si]
        blk = shard
        st = Step()
        for g in groups(si):
            for a in g:
                for b in g:
                    if a != b:
                        st.transfers.append(Transfer(ids[a], ids[b], blk))
        p.steps.append(st)
        shard = shard * f
    return p


def factorizations(n: int, max_factor: int | None = None,
                   max_steps: int = 3) -> list[list[int]]:
    """All ordered factorizations of n into 2..max_steps factors ≥2
    (optionally capped per-factor). Used by GenTree's plan-type search."""
    out: list[list[int]] = []

    def rec(rem: int, cur: list[int]):
        if len(cur) >= 2 and rem == 1:
            out.append(list(cur))
            return
        if len(cur) >= max_steps and rem != 1:
            return
        if rem == 1:
            return
        f = 2
        while f <= rem:
            if rem % f == 0 and (max_factor is None or f <= max_factor):
                cur.append(f)
                rec(rem // f, cur)
                cur.pop()
            f += 1

    rec(n, [])
    return out
