"""The paper's optimality results as checkable predicates (§3.3).

Theorem 1: memory-access lower bound  D_min = (N+1)·S/N  memory ops.
Theorem 2: no plan is simultaneously δ-optimal and ε-optimal when N > w_t.
These are used by property-based tests and by the sync-strategy chooser.
"""
from __future__ import annotations

from .plans import Plan


def delta_lower_bound_mem_ops(n: int, size: float) -> float:
    """Theorem 1: min total memory ops of any AllReduce = (N+1)·S/N."""
    return (n + 1) * size / n


def is_delta_optimal(plan: Plan, rel_tol: float = 1e-6) -> bool:
    """Compares the *parallel* per-server memory cost against Theorem 1's
    (N+1)S/N bound (servers reduce their blocks concurrently)."""
    lb = delta_lower_bound_mem_ops(plan.n, plan.size)
    return plan.max_mem_ops_per_server() <= lb * (1.0 + rel_tol)


def is_epsilon_optimal(plan: Plan, w_t: int) -> bool:
    """ε-optimal ⇔ no step has receive fan-in above the incast threshold."""
    return plan.max_fan_in() <= w_t


def theorem2_holds(plan: Plan, w_t: int) -> bool:
    """No plan may be both δ- and ε-optimal when N > w_t (Theorem 2)."""
    if plan.n <= w_t:
        return True
    return not (is_delta_optimal(plan) and is_epsilon_optimal(plan, w_t))


def mem_ops_with_h_steps(n: int, size: float, h: int) -> float:
    """Eq. (15): T = (N − 1 + 2h)·S/N·δ  — memory ops for h-step reduction."""
    return (n - 1 + 2 * h) * size / n
