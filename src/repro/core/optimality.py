"""The paper's optimality results as checkable predicates (§3.3).

Theorem 1: memory-access lower bound  D_min = (N+1)·S/N  memory ops.
Theorem 2: no plan is simultaneously δ-optimal and ε-optimal when N > w_t.
These are used by property-based tests and by the sync-strategy chooser.
"""
from __future__ import annotations

from .plans import Plan


def delta_lower_bound_mem_ops(n: int, size: float) -> float:
    """Theorem 1: min total memory ops of any AllReduce = (N+1)·S/N."""
    return (n + 1) * size / n


def is_delta_optimal(plan: Plan, rel_tol: float = 1e-6) -> bool:
    """Compares the *parallel* per-server memory cost against Theorem 1's
    (N+1)S/N bound (servers reduce their blocks concurrently)."""
    lb = delta_lower_bound_mem_ops(plan.n, plan.size)
    return plan.max_mem_ops_per_server() <= lb * (1.0 + rel_tol)


def is_epsilon_optimal(plan: Plan, w_t: int) -> bool:
    """ε-optimal ⇔ no step has receive fan-in above the incast threshold."""
    return plan.max_fan_in() <= w_t


def theorem2_holds(plan: Plan, w_t: int) -> bool:
    """No plan may be both δ- and ε-optimal when N > w_t (Theorem 2)."""
    if plan.n <= w_t:
        return True
    return not (is_delta_optimal(plan) and is_epsilon_optimal(plan, w_t))


def mem_ops_with_h_steps(n: int, size: float, h: int) -> float:
    """Eq. (15): T = (N − 1 + 2h)·S/N·δ  — memory ops for h-step reduction."""
    return (n - 1 + 2 * h) * size / n


# ---------------------------------------------------------------------------
# Overlap-adjusted pipeline bounds (DESIGN.md §15)
# ---------------------------------------------------------------------------
def overlap_lower_bound(t_rs: float, t_ag: float, k: int) -> float:
    """Lower bound on any k-bucket RS/AG pipeline, contention included.

    Each steady-state round runs one RS and one AG concurrently; the
    per-link occupancy merge can never price a joint round below
    max(T_RS, T_AG) — a merged round still carries every unit of the
    slower half on its busiest link — so the optimistic
    `bucketing.pipelined_time` (t_joint = max) is a true lower bound for
    EVERY issuance policy, merged or sequential."""
    from .bucketing import pipelined_time
    return pipelined_time(t_rs, t_ag, k)


def overlap_upper_bound(t_rs: float, t_ag: float, k: int) -> float:
    """Upper bound: a joint round never exceeds T_RS + T_AG (sequential
    issuance is always available), so the contended pipeline is at most
    `bucketing.serial_time` — the no-overlap schedule."""
    from .bucketing import serial_time
    return serial_time(t_rs, t_ag, k)


def overlap_certificate(t_rs: float, t_ag: float, k: int,
                        t_contended: float,
                        rel_tol: float = 1e-9) -> dict:
    """Checkable certificate for a contended pipeline quote: the quote
    must be sandwiched between the overlap-adjusted lower bound and the
    sequential upper bound. `gap_ratio` = (quoted − lower) / lower is the
    price of contention — 0 means the links were disjoint enough for the
    optimistic model to be exact. Quoted on `StepPlan` pipeline quotes
    and checked by tests/test_overlap.py."""
    lb = overlap_lower_bound(t_rs, t_ag, k)
    ub = overlap_upper_bound(t_rs, t_ag, k)
    q = float(t_contended)
    slack = rel_tol * max(1.0, lb, ub)
    return {
        "k": int(k), "t_rs": float(t_rs), "t_ag": float(t_ag),
        "lower_bound": float(lb), "upper_bound": float(ub),
        "quoted": q,
        "sandwiched": bool(lb - slack <= q <= ub + slack),
        "gap_ratio": float((q - lb) / lb) if lb > 0 else 0.0,
    }
