"""GenTree — recursive AllReduce plan generation on tree topologies (paper §4).

Faithful reimplementation of Algorithms 1 & 2:

  * Algorithm 1 (`generate_basic_plan`): bottom-up computation of the
    initial/final data placement for every switch-local sub-tree. Each block
    is assigned to a destination server that already holds it under some
    child, preferring its own child's holdings ("taken" bookkeeping).
  * Algorithm 2 (`generate_final_plan`): per switch, (a) the *data
    rearrangement* decision per child (aggregate the child's scattered
    results onto a subset sized by the uplink convergence ratio before
    crossing the switch) and (b) *plan type selection* among
    CPS / m×n HCPS / Ring / RHD (balanced children) or Asymmetric CPS
    (unbalanced), each candidate priced by GenModel — here, by simulating
    the candidate's step IR with the incast-aware simulator, which embodies
    Eq. (11) on the actual tree.

The output is a complete AllReduce Plan IR (ReduceScatter + mirrored
AllGather), the per-switch decisions, and the predicted time.

Candidate search runs in one of two modes (DESIGN.md §7):

  * engine="fast" (default): candidates are *lowered* straight to integer
    holder/destination arrays (`_lowered_*`), every candidate for a switch
    is priced in one batched `FastEngine.totals` call, the shared
    `pre_steps` prefix (rearrangement moves) is compiled once and its cost
    reused across candidates, and only the winning candidate is
    materialized back into Plan IR.
  * engine="reference": the original per-candidate IR construction +
    pure-Python simulation, kept verbatim as the equivalence oracle and as
    the pre-PR baseline for `benchmarks/simfast_bench.py`'s speedup gate.

Both modes must select identical per-switch decisions (pinned in
tests/test_simfast.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .cost_model import GenModelParams, PAPER_TABLE5
from .plans import Plan, ReduceOp, Step, Transfer, factorizations, ring as ring_plan, \
    rhd as rhd_plan, cps as cps_plan, hcps as hcps_plan
from .simulator import Simulator
from .topology import TopoNode


@dataclass
class SwitchDecision:
    algo: str
    factors: list[int] | None = None
    rearrange: dict[int, int] = field(default_factory=dict)  # child idx -> subset size
    cost: float = 0.0


@dataclass
class GenTreeResult:
    plan: Plan
    decisions: dict[str, SwitchDecision]
    predicted_time: float


# ---------------------------------------------------------------------------
# Algorithm 1 — basic placement
# ---------------------------------------------------------------------------
def generate_basic_plan(node: TopoNode, n_total: int,
                        place: dict[str, dict[int, list[int]]]) -> None:
    if node.is_server:
        place[node.name] = {node._sid: list(range(n_total))}
        return
    for c in node.children:
        generate_basic_plan(c, n_total, place)

    servers = node.server_ids()
    n = len(servers)
    num_blocks = n_total // n
    remain = n_total % n
    taken = [False] * n_total
    final: dict[int, list[int]] = {}
    quota: dict[int, int] = {}
    for c in node.children:
        for server, blocks in place[c.name].items():
            q = num_blocks + (1 if remain > 0 else 0)
            if remain > 0:
                remain -= 1
            quota[server] = q
            final[server] = []
            for b in blocks:
                if not taken[b]:
                    taken[b] = True
                    final[server].append(b)
                    q -= 1
                    if q == 0:
                        break
            quota[server] = q
    # Fix-up: hand any still-untaken blocks to servers with remaining quota.
    leftovers = [b for b in range(n_total) if not taken[b]]
    if leftovers:
        it = iter(leftovers)
        for server in final:
            while quota[server] > 0:
                try:
                    b = next(it)
                except StopIteration:
                    break
                final[server].append(b)
                taken[b] = True
                quota[server] -= 1
    place[node.name] = final


# ---------------------------------------------------------------------------
# Switch-local exchange IR builders (cross-children copy combining)
# ---------------------------------------------------------------------------
def _index_holders(children_places: list[dict[int, list[int]]],
                   n_total: int) -> list[dict[int, int]]:
    out = []
    for cp in children_places:
        m: dict[int, int] = {}
        for srv, blocks in cp.items():
            for b in blocks:
                m[b] = srv
        out.append(m)
    return out


def _exchange_steps_direct(holders: list[dict[int, int]],
                           dest: dict[int, int], unit: float) -> list[Step]:
    """One-shot CPS/ACPS across children: every copy goes straight to the
    destination server; one fused reduce of fan-in = #children there."""
    st = Step()
    recv_count: dict[tuple[int, int], int] = {}
    for hmap in holders:
        for b, h in hmap.items():
            d = dest[b]
            if h != d:
                st.transfers.append(Transfer(h, d, unit, blocks=(b,)))
            recv_count[(d, b)] = recv_count.get((d, b), 0) + 1
    for (d, b), c in recv_count.items():
        if c > 1:
            st.reduces.append(ReduceOp(d, c, unit, blocks=(b,)))
    return [st]


def _exchange_steps_hcps(holders: list[dict[int, int]],
                         dest: dict[int, int], unit: float,
                         factors: list[int]) -> list[Step]:
    """Staged combining of the c copies with fan-in factors[i] per stage."""
    cur = [dict(h) for h in holders]
    steps: list[Step] = []
    radix = 1
    for si, f in enumerate(factors):
        last = si == len(factors) - 1
        st = Step()
        nxt: list[dict[int, int]] = []
        for gstart in range(0, len(cur), f):
            group = cur[gstart:gstart + f]
            merged: dict[int, int] = {}
            for b in group[0]:
                cands = [g[b] for g in group]
                if last:
                    recv = dest[b]
                elif dest[b] in cands:
                    # keep the copy on the destination's side when possible
                    recv = dest[b]
                else:
                    # balanced, orthogonal receiver choice: pick the group
                    # member by the block's mixed-radix digit for this stage
                    recv = cands[(b // radix) % f]
                fan = 0
                for g in group:
                    h = g[b]
                    if h != recv:
                        st.transfers.append(Transfer(h, recv, unit,
                                                     blocks=(b,)))
                    fan += 1
                if fan > 1:
                    st.reduces.append(ReduceOp(recv, fan, unit, blocks=(b,)))
                merged[b] = recv
            nxt.append(merged)
        cur = nxt
        radix *= f
        steps.append(st)
    return steps


def _exchange_steps_chain(holders: list[dict[int, int]],
                          dest: dict[int, int], unit: float) -> list[Step]:
    """Ring-like pairwise chain across the c copies: c-1 steps, fan-in 2.

    Per block the chain visits every child's copy, ordered so a copy
    already sitting on the destination server is folded LAST — the chain
    then ends at dest with no extra hop. Blocks whose destination holds no
    copy need one trailing movement step. (The pre-block-IR version folded
    the accumulator "at dest" on the last step even when the last child's
    copy never moved there — unexecutable and underpriced.)"""
    c = len(holders)
    steps = [Step() for _ in range(c - 1)]
    move = Step()
    for b in holders[0]:
        hs = [h[b] for h in holders]
        order = list(range(c))
        for j, h in enumerate(hs):
            if h == dest[b]:
                order = order[:j] + order[j + 1:] + [j]
                break
        acc = hs[order[0]]
        for k, j in enumerate(order[1:]):
            nxt = hs[j]
            if acc != nxt:
                steps[k].transfers.append(Transfer(acc, nxt, unit,
                                                   blocks=(b,)))
            steps[k].reduces.append(ReduceOp(nxt, 2, unit, blocks=(b,)))
            acc = nxt
        if acc != dest[b]:
            move.transfers.append(Transfer(acc, dest[b], unit, blocks=(b,)))
    if move.transfers:
        steps.append(move)
    return steps


def _exchange_steps_rhd(holders: list[dict[int, int]],
                        dest: dict[int, int], unit: float) -> list[Step]:
    """Pairwise-tree combining (RHD reduce side) across c copies, c po2."""
    cur = [dict(h) for h in holders]
    steps: list[Step] = []
    while len(cur) > 1:
        last = len(cur) == 2
        st = Step()
        nxt = []
        for i in range(0, len(cur), 2):
            a, b_ = cur[i], cur[i + 1]
            merged = {}
            for blk in a:
                recv = dest[blk] if last else (
                    dest[blk] if dest[blk] in (a[blk], b_[blk]) else a[blk])
                for side in (a[blk], b_[blk]):
                    if side != recv:
                        st.transfers.append(Transfer(side, recv, unit,
                                                     blocks=(blk,)))
                st.reduces.append(ReduceOp(recv, 2, unit, blocks=(blk,)))
                merged[blk] = recv
            nxt.append(merged)
        cur = nxt
        steps.append(st)
    return steps


def _rearrange_step(child_place: dict[int, list[int]], subset: list[int],
                    unit: float) -> tuple[Step, dict[int, list[int]]]:
    """Aggregate a child's scattered blocks onto the `subset` servers
    (paper's data-rearrangement optimization). Pure data movement."""
    st = Step()
    new_place: dict[int, list[int]] = {s: [] for s in subset}
    i = 0
    for srv in sorted(child_place):
        for b in child_place[srv]:
            tgt = subset[i % len(subset)]
            i += 1
            if tgt != srv:
                st.transfers.append(Transfer(srv, tgt, unit, blocks=(b,)))
            new_place[tgt].append(b)
    return st, new_place


# ---------------------------------------------------------------------------
# Lowered (array-form) candidate builders — the batched search path.
#
# A candidate step is (src, dst, blk, red_srv, red_blk, fan): integer arrays
# of transfer endpoints + the block id each transfer carries, plus the
# reduce servers and the block each reduce folds; every transfer/reduce is
# sized `unit`. Each builder mirrors its `_exchange_steps_*` IR twin
# transfer-for-transfer (same multiset per step), so compiled costs match
# the reference engine; the block arrays ride along for free and are only
# touched when the winner is materialized back into (executable) Plan IR.
# ---------------------------------------------------------------------------
def _holder_row(child_place: dict[int, list[int]], n_total: int) -> np.ndarray:
    """block → holding server, as a dense array (the array `_index_holders`)."""
    row = np.empty(n_total, dtype=np.int64)
    for srv, blocks in child_place.items():
        row[blocks] = srv
    return row


def _lowered_direct(H: np.ndarray, D: np.ndarray) -> list[tuple]:
    c, B = H.shape
    mask = H != D
    src = H[mask]
    dst = np.broadcast_to(D, H.shape)[mask]
    blk = np.broadcast_to(np.arange(B), H.shape)[mask]
    rsrv = D if c > 1 else D[:0]
    rblk = np.arange(B) if c > 1 else np.arange(0)
    return [(src, dst, blk, rsrv, rblk, c)]


def _lowered_hcps(H: np.ndarray, D: np.ndarray,
                  factors: list[int]) -> list[tuple]:
    B = H.shape[1]
    blocks = np.arange(B)
    cur = H
    steps = []
    radix = 1
    for si, f in enumerate(factors):
        last = si == len(factors) - 1
        G = cur.reshape(-1, f, B)
        ng = G.shape[0]
        if last:
            recv = np.broadcast_to(D, (ng, B))
        else:
            has_dest = (G == D).any(axis=1)
            dig = (blocks // radix) % f
            pick = np.take_along_axis(
                G, np.broadcast_to(dig, (ng, 1, B)), axis=1)[:, 0, :]
            recv = np.where(has_dest, D, pick)
        mask = G != recv[:, None, :]
        src = G[mask]
        dst = np.broadcast_to(recv[:, None, :], G.shape)[mask]
        blk = np.broadcast_to(blocks, G.shape)[mask]
        steps.append((src, dst, blk, recv.ravel(),
                      np.broadcast_to(blocks, (ng, B)).ravel(), f))
        cur = recv
        radix *= f
    return steps


def _lowered_chain(H: np.ndarray, D: np.ndarray) -> list[tuple]:
    c, B = H.shape
    blocks = np.arange(B)
    # Per block, fold the copy already sitting on the destination LAST
    # (mirrors _exchange_steps_chain): stable argsort on a key that pushes
    # the first dest-holding child to the end of the visit order.
    eq = H == D
    has_dest = eq.any(axis=0)
    first_dest = np.argmax(eq, axis=0)
    child = np.arange(c)[:, None]
    key = np.where(has_dest & (child == first_dest), c, child)
    order = np.argsort(np.broadcast_to(key, H.shape), axis=0, kind="stable")
    Hord = np.take_along_axis(H, order, axis=0)
    acc = Hord[0]
    steps = []
    for i in range(1, c):
        nxt = Hord[i]
        mask = acc != nxt
        steps.append((acc[mask], nxt[mask], blocks[mask], nxt, blocks, 2))
        acc = nxt
    mask = acc != D
    if mask.any():
        steps.append((acc[mask], D[mask], blocks[mask],
                      D[:0], blocks[:0], 2))
    return steps


def _lowered_rhd(H: np.ndarray, D: np.ndarray) -> list[tuple]:
    B = H.shape[1]
    blocks = np.arange(B)
    cur = H
    steps = []
    while cur.shape[0] > 1:
        last = cur.shape[0] == 2
        a, b = cur[0::2], cur[1::2]
        if last:
            recv = np.broadcast_to(D, a.shape)
        else:
            recv = np.where((a == D) | (b == D), D, a)
        ma, mb = a != recv, b != recv
        src = np.concatenate([a[ma], b[mb]])
        dst = np.concatenate([np.broadcast_to(recv, a.shape)[ma],
                              np.broadcast_to(recv, b.shape)[mb]])
        bb = np.broadcast_to(blocks, a.shape)
        blk = np.concatenate([bb[ma], bb[mb]])
        steps.append((src, dst, blk, recv.ravel(),
                      np.broadcast_to(blocks, recv.shape).ravel(), 2))
        cur = recv
    return steps


def _compile_lowered(eng, steps: list[tuple], unit: float) -> list:
    out = []
    for src, dst, _blk, rsrv, _rblk, fan in steps:
        out.append(eng.compile_arrays(
            src, dst, unit, rsrv,
            (fan - 1) * unit, (fan + 1) * unit))
    return out


def _materialize(steps: list[tuple], unit: float) -> list[Step]:
    """Winning lowered candidate → Plan IR (only the winner pays this)."""
    out = []
    for src, dst, blk, rsrv, rblk, fan in steps:
        st = Step()
        st.transfers = [Transfer(s, d, unit, blocks=(b,))
                        for s, d, b in zip(src.tolist(), dst.tolist(),
                                           blk.tolist())]
        st.reduces = [ReduceOp(r, fan, unit, blocks=(b,))
                      for r, b in zip(rsrv.tolist(), rblk.tolist())]
        out.append(st)
    return out


# ---------------------------------------------------------------------------
# Algorithm 2 + assembly
# ---------------------------------------------------------------------------
def _merge_concurrent(step_lists: list[list[Step]]) -> list[Step]:
    """Zip-merge step lists of sibling switches (disjoint servers)."""
    out: list[Step] = []
    depth = max((len(sl) for sl in step_lists), default=0)
    for i in range(depth):
        st = Step()
        for sl in step_lists:
            if i < len(sl):
                st.transfers.extend(sl[i].transfers)
                st.reduces.extend(sl[i].reduces)
        out.append(st)
    return out


def _mirror(steps: list[Step]) -> list[Step]:
    """AllGather = reversed ReduceScatter with src/dst swapped, no reduces.
    Block annotations carry over: the mirrored transfer redistributes the
    finished value of the same blocks back along the reduce path."""
    out = []
    for st in reversed(steps):
        m = Step()
        m.transfers = [Transfer(t.dst, t.src, t.size, blocks=t.blocks)
                       for t in st.transfers]
        out.append(m)
    return out


def _switch_search_fast(eng, sw: TopoNode, place, eff_place, unit: float,
                        n_total: int, candidates, enable_rearrangement,
                        max_hcps_steps) -> tuple[list[Step], SwitchDecision]:
    """Batched, incremental Algorithm-2 search for one switch: lowered
    candidates, one `totals` call, pre_steps compiled once, winner-only
    IR materialization. Decision-equivalent to the reference branch."""
    D = np.empty(n_total, dtype=np.int64)
    for srv, blocks in place[sw.name].items():
        D[blocks] = srv
    c = len(sw.children)
    dec = SwitchDecision(algo="?")
    pre_ir: list[Step] = []
    pre_cost = 0.0

    # ---- rearrangement decision per child (Algorithm 2, lines 8-16).
    # The child's holder row doubles as the probe input, so each probe
    # compiles two one-step plans instead of re-simulating from IR.
    rows = []
    for ci, ch in enumerate(sw.children):
        cp = eff_place[ch.name]
        row = _holder_row(cp, n_total)
        if enable_rearrangement and not ch.is_server and len(cp) > 1:
            gc_bw = max(ch.children[0].uplink_bw, 1.0)
            k = max(1, min(len(ch.children),
                           -(-int(ch.uplink_bw) // int(gc_bw))))
            subset = [s for cc in ch.children[:k]
                      for s in cc.server_ids() if s in cp]
            if not subset:
                subset = sorted(cp)[:1]
            if len(subset) < len(cp):
                rstep, rplace = _rearrange_step(cp, subset, unit)
                row_r = _holder_row(rplace, n_total)
                rstep_cost = eng.step_cost(eng.compile_step(rstep))[0]
                probe_o = eng.total(_compile_lowered(
                    eng, _lowered_direct(row[None, :], D), unit))
                probe_r = rstep_cost + eng.total(_compile_lowered(
                    eng, _lowered_direct(row_r[None, :], D), unit))
                if probe_r < probe_o:
                    pre_ir.append(rstep)
                    pre_cost += rstep_cost
                    row = row_r
                    dec.rearrange[ci] = len(subset)
        rows.append(row)
    H = np.stack(rows)
    balanced = len({ch.num_servers() for ch in sw.children}) == 1

    # ---- plan type selection (Algorithm 2, lines 17-29), batched
    cands: list[tuple[str, list[int] | None, list[tuple]]] = []
    if balanced and c > 1:
        if "cps" in candidates:
            cands.append(("cps", None, _lowered_direct(H, D)))
        if "hcps" in candidates:
            for fac in factorizations(c, max_steps=max_hcps_steps):
                cands.append(("hcps", fac, _lowered_hcps(H, D, fac)))
        if "ring" in candidates and c > 2:
            cands.append(("ring", None, _lowered_chain(H, D)))
        if "rhd" in candidates and c > 1 and (c & (c - 1)) == 0:
            cands.append(("rhd", None, _lowered_rhd(H, D)))
    if not cands:
        cands.append(("acps", None, _lowered_direct(H, D)))

    costs = eng.totals([_compile_lowered(eng, steps, unit)
                        for _, _, steps in cands])
    bi = min(range(len(cands)),
             key=lambda i: (pre_cost + costs[i], cands[i][0],
                            tuple(cands[i][1] or ())))
    dec.algo, dec.factors = cands[bi][0], cands[bi][1]
    dec.cost = pre_cost + costs[bi]
    return pre_ir + _materialize(cands[bi][2], unit), dec


def _switch_search_reference(sim: Simulator, sw: TopoNode, place, eff_place,
                             unit: float, n_total: int, size: float,
                             candidates, enable_rearrangement,
                             max_hcps_steps) -> tuple[list[Step],
                                                      SwitchDecision]:
    """The pre-PR search: per-candidate IR construction + full simulation
    (including re-simulating the shared pre_steps prefix per candidate).
    Kept verbatim as the oracle the fast path is tested against."""
    def _eval(steps: list[Step]) -> float:
        return sim.simulate(Plan("tmp", n_total, size, steps=steps)).total

    dest = {}
    for srv, blocks in place[sw.name].items():
        for b in blocks:
            dest[b] = srv
    c = len(sw.children)
    dec = SwitchDecision(algo="?")
    pre_steps: list[Step] = []

    # ---- rearrangement decision per child (Algorithm 2, lines 8-16)
    # Subset = the servers under the first k of the child's own
    # children, k sized by the convergence ratio (paper §4.2): the
    # child's uplink bandwidth over one grandchild sub-tree's
    # uplink — enough senders to saturate the bottleneck, no more.
    child_places = []
    for ci, ch in enumerate(sw.children):
        cp = eff_place[ch.name]
        if (enable_rearrangement and not ch.is_server
                and len(cp) > 1):
            gc_bw = max(ch.children[0].uplink_bw, 1.0)
            k = max(1, min(len(ch.children),
                           -(-int(ch.uplink_bw) // int(gc_bw))))
            subset = [s for cc in ch.children[:k]
                      for s in cc.server_ids() if s in cp]
            if not subset:
                subset = sorted(cp)[:1]
            if len(subset) < len(cp):
                rstep, rplace = _rearrange_step(cp, subset, unit)
                # cost with vs without rearrangement for this child's
                # outbound traffic (priced on the direct exchange)
                probe_o = _exchange_steps_direct(
                    _index_holders([cp], n_total), dest, unit)
                probe_r = [rstep] + _exchange_steps_direct(
                    _index_holders([rplace], n_total), dest, unit)
                if _eval(probe_r) < _eval(probe_o):
                    pre_steps.append(rstep)
                    cp = rplace
                    dec.rearrange[ci] = len(subset)
        child_places.append(cp)

    holders = _index_holders(child_places, n_total)
    balanced = len({ch.num_servers() for ch in sw.children}) == 1

    # ---- plan type selection (Algorithm 2, lines 17-29)
    cands: list[tuple[str, list[int] | None, list[Step]]] = []
    if balanced and c > 1:
        if "cps" in candidates:
            cands.append(("cps", None,
                          _exchange_steps_direct(holders, dest, unit)))
        if "hcps" in candidates:
            for fac in factorizations(c, max_steps=max_hcps_steps):
                cands.append(("hcps", fac, _exchange_steps_hcps(
                    holders, dest, unit, fac)))
        if "ring" in candidates and c > 2:
            cands.append(("ring", None,
                          _exchange_steps_chain(holders, dest, unit)))
        if "rhd" in candidates and c > 1 and (c & (c - 1)) == 0:
            cands.append(("rhd", None,
                          _exchange_steps_rhd(holders, dest, unit)))
    if not cands:
        cands.append(("acps", None,
                      _exchange_steps_direct(holders, dest, unit)))

    best = min(cands, key=lambda x: (_eval(pre_steps + x[2]),
                                     x[0], tuple(x[1] or ())))
    dec.algo, dec.factors = best[0], best[1]
    dec.cost = _eval(pre_steps + best[2])
    return pre_steps + best[2], dec


def gentree(topo: TopoNode, size: float,
            params: dict[str, GenModelParams] | None = None,
            candidates: tuple[str, ...] = ("cps", "hcps", "ring", "rhd"),
            enable_rearrangement: bool = True,
            max_hcps_steps: int = 3,
            concurrent: bool = True,
            engine: str | None = None) -> GenTreeResult:
    """concurrent=True zip-merges sibling switch-local sub-plans (they
    touch disjoint servers and links, so real hardware runs them in
    parallel) — a beyond-paper scheduling improvement. concurrent=False
    reproduces the paper's stream-emulator behaviour (sub-plans issued
    sequentially), for apples-to-apples Table-7 comparisons.

    engine selects the candidate-pricing path: "fast" (default via
    Simulator / $REPRO_SIM_ENGINE) runs the batched compiled search,
    "reference" the pre-PR pure-Python one; both pick identical plans."""
    params = params or PAPER_TABLE5
    topo.finalize()
    n_total = topo.num_servers()
    unit = size / n_total
    sim = Simulator(topo, params, engine=engine)
    fast = sim.engine == "fast"
    eng = sim.fast_engine() if fast else None

    place: dict[str, dict[int, list[int]]] = {}
    generate_basic_plan(topo, n_total, place)

    decisions: dict[str, SwitchDecision] = {}
    # switches bottom-up, grouped by depth for concurrent merging
    depth_of: dict[str, int] = {}

    def _depth(node: TopoNode) -> int:
        if node.is_server:
            return 0
        d = 1 + max(_depth(c) for c in node.children)
        depth_of[node.name] = d
        return d

    _depth(topo)
    max_depth = depth_of.get(topo.name, 1)
    switches = topo.switches()

    rs_levels: list[list[Step]] = []
    # effective placement per child after its own subtree finished (+rearr)
    eff_place: dict[str, dict[int, list[int]]] = dict(place)

    for depth in range(1, max_depth + 1):
        level_steps: list[list[Step]] = []
        for sw in [s for s in switches if depth_of[s.name] == depth]:
            if fast:
                steps, dec = _switch_search_fast(
                    eng, sw, place, eff_place, unit, n_total,
                    candidates, enable_rearrangement, max_hcps_steps)
            else:
                steps, dec = _switch_search_reference(
                    sim, sw, place, eff_place, unit, n_total, size,
                    candidates, enable_rearrangement, max_hcps_steps)
            decisions[sw.name] = dec
            level_steps.append(steps)
            eff_place[sw.name] = place[sw.name]
        if concurrent:
            rs_levels.append(_merge_concurrent(level_steps))
        else:
            rs_levels.append([st for sl in level_steps for st in sl])

    rs_steps = [st for lvl in rs_levels for st in lvl]
    ag_steps = _mirror(rs_steps)
    full = Plan("gentree", n_total, size, steps=rs_steps + ag_steps,
                num_blocks=n_total)
    return GenTreeResult(plan=full, decisions=decisions,
                         predicted_time=sim.simulate(full).total)


# ---------------------------------------------------------------------------
# Baseline global plans routed over a tree (for Table 7 comparisons)
# ---------------------------------------------------------------------------
def baseline_plan(kind: str, topo: TopoNode, size: float) -> Plan:
    topo.finalize()
    ids = topo.server_ids()
    n = len(ids)
    if kind == "ring":
        return ring_plan(n, size, servers=ids)
    if kind == "rhd":
        return rhd_plan(n, size, servers=ids)
    if kind == "cps":
        return cps_plan(n, size, servers=ids)
    if kind.startswith("hcps:"):
        fac = [int(x) for x in kind.split(":", 1)[1].split("x")]
        return hcps_plan(fac, size, servers=ids)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Standalone per-family plans over a tree (ISSUE 9): the folding families
# are the Kolmakov–Zhang halves of the co-planned GenTree AllReduce —
# executing RS or AG alone runs exactly the half the AllReduce would —
# while the pure-movement families are flat single-step exchanges over
# the tree's server ids.
# ---------------------------------------------------------------------------
def family_plan(family: str, topo: TopoNode, size: float,
                params: dict[str, GenModelParams] | None = None,
                engine: str | None = None, **gentree_kwargs) -> Plan:
    from .plans import alltoall_plan, family_halves, p2p_plan
    topo.finalize()
    if family == "allreduce":
        return gentree(topo, size, params, engine=engine,
                       **gentree_kwargs).plan
    if family in ("reduce_scatter", "allgather"):
        res = gentree(topo, size, params, engine=engine, **gentree_kwargs)
        rs_half, ag_half = family_halves(res.plan)
        return rs_half if family == "reduce_scatter" else ag_half
    ids = topo.server_ids()
    if family == "all_to_all":
        return alltoall_plan(len(ids), size, servers=ids)
    if family == "p2p":
        return p2p_plan(len(ids), size, servers=ids)
    raise ValueError(f"unknown collective family {family!r}")
