"""Batched serving driver: continuous prefill + decode over a request queue.

Greedy sampling over the reduced-config model on local devices; the
full-scale serve_step (one token, KV cache of seq_len) is exercised by
launch.dryrun's decode cells. Demonstrates the inference side of the
framework: cache init, prefill, step loop, per-request stop handling.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.config import smoke_config
from repro.models.registry import build


@dataclasses.dataclass
class ServeConfig:
    arch: str = "stablelm-12b"
    batch: int = 4
    prompt_len: int = 32
    max_new: int = 32
    cache_len: int = 128
    seed: int = 0


def serve(sc: ServeConfig, smoke: bool = True, on_log=print) -> dict:
    cfg = get_config(sc.arch)
    if smoke:
        cfg = smoke_config(cfg)
    api = build(cfg)

    # Pre-warm the shared plan cache with the tensor-parallel decode
    # AllReduce shape (one per layer, batch × d_model activations over the
    # local devices) and report the plan a TP deployment of this config
    # would execute via collectives.allreduce_planned. This driver's decode
    # loop itself is single-host (api.decode_step), so the plan is
    # advisory here; it is returned so callers can act on it.
    from repro.planner.service import default_service
    tp_plans = default_service().get_axis_plans(
        [("model", len(jax.devices()))], float(sc.batch * cfg.d_model))
    if tp_plans:
        desc = ", ".join(f"{p.axis}:{p.strategy}{list(p.factors) if p.factors else ''}"
                         for p in tp_plans)
        on_log(f"planner: decode AllReduce plan {desc}")
    else:
        on_log("planner: single device, no decode collective needed")
    key = jax.random.PRNGKey(sc.seed)
    params = api.init_params(key)

    prompts = jax.random.randint(
        jax.random.fold_in(key, 1), (sc.batch, sc.prompt_len), 0, cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch = {"embeds": jax.random.normal(
            key, (sc.batch, sc.prompt_len, cfg.d_model), jnp.bfloat16),
            "mrope_positions": jnp.tile(
                jnp.arange(sc.prompt_len, dtype=jnp.int32)[None, None],
                (3, sc.batch, 1))}
    elif cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (sc.batch, 32, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(lambda p, b: api.prefill(p, b, cache_len=sc.cache_len))
    decode = jax.jit(api.decode_step)

    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
    out = [np.asarray(tok)]
    for i in range(sc.max_new - 1):
        step_batch = {"tokens": tok[:, None]}
        if cfg.family == "vlm":
            emb = jnp.take(params["embed"], tok[:, None], axis=0)
            step_batch = {"embeds": emb}
        logits, cache = decode(params, cache, step_batch)
        tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        out.append(np.asarray(tok))
    gen = np.stack(out, axis=1)
    on_log(f"served batch={sc.batch} prompt={sc.prompt_len} "
           f"new={sc.max_new}: first row {gen[0][:8].tolist()}...")
    return {"tokens": gen, "tp_plans": tp_plans}


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()
    serve(ServeConfig(arch=args.arch, batch=args.batch,
                      max_new=args.max_new))


if __name__ == "__main__":
    main()
