"""Batched serving driver: continuous prefill + decode over a request queue.

Greedy sampling over the reduced-config model on local devices; the
full-scale serve_step (one token, KV cache of seq_len) is exercised by
launch.dryrun's decode cells. Demonstrates the inference side of the
framework: cache init, prefill, step loop, per-request stop handling.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.config import smoke_config
from repro.models.registry import build
from repro.runtime.metrics import default_metrics
from repro.runtime.trace import default_tracer


@dataclasses.dataclass
class ServeConfig:
    arch: str = "stablelm-12b"
    batch: int = 4
    prompt_len: int = 32
    max_new: int = 32
    cache_len: int = 128
    seed: int = 0


def serve(sc: ServeConfig, smoke: bool = True, on_log=print) -> dict:
    cfg = get_config(sc.arch)
    if smoke:
        cfg = smoke_config(cfg)
    api = build(cfg)

    # Pre-warm the shared plan cache with the tensor-parallel decode
    # AllReduce shape (one per layer, batch × d_model activations over the
    # local devices) and lower the GenTree plan to its executable schedule
    # (DESIGN.md §8). With ≥2 local devices the schedule is executed once
    # under shard_map against lax.psum as a deployment self-check; the
    # decode loop itself is single-host (api.decode_step), so on one
    # device the schedule stays advisory. Returned so callers can act on
    # it (a TP deployment hands it to collectives.allreduce).
    from repro.core.lower import LoweringError
    from repro.planner.service import default_service
    n_dev = len(jax.devices())
    tp_exec = None
    if n_dev > 1:
        try:
            tp_exec = default_service().get_axis_executable(
                "model", n_dev, float(sc.batch * cfg.d_model))
        except LoweringError as e:
            # e.g. a warm disk cache written before block annotations:
            # keep serving on the advisory flat labels, as pre-§8 builds
            tp_plans = default_service().get_axis_plans(
                [("model", n_dev)], float(sc.batch * cfg.d_model))
            desc = ", ".join(
                f"{p.axis}:{p.strategy}{list(p.factors) if p.factors else ''}"
                for p in tp_plans)
            on_log(f"planner: plan not lowerable ({e}); advisory decode "
                   f"plan {desc}")
    if tp_exec is not None:
        # guarded execution (DESIGN.md §12): a failing planned schedule
        # falls back to flat lax.psum instead of failing the deployment
        from repro.core.lower import guard_schedule
        sched = guard_schedule(
            tp_exec.schedule,
            telemetry=default_service().telemetry)
        on_log(f"planner: decode AllReduce executes {tp_exec.algo} plan "
               f"({sched.describe()})")
        from jax.sharding import PartitionSpec as P
        from repro.core.compat import shard_map
        mesh = jax.make_mesh((n_dev,), ("model",))
        probe = jax.random.normal(
            jax.random.PRNGKey(2), (n_dev, sc.batch * cfg.d_model))
        # jitted: an un-jitted shard_map re-traces (and re-dispatches
        # eagerly, round by round) on every call — compiling once makes
        # the self-check ~100x faster on host devices AND gives the
        # timing loop below an executable that measures the collective,
        # not the tracer
        f = jax.jit(shard_map(
            lambda v: sched.allreduce(v[0], "model")[None],
            mesh=mesh, in_specs=P("model"), out_specs=P("model")))
        with default_tracer().span("serve/self_check", n=n_dev,
                                   algo=tp_exec.algo):
            got = np.asarray(f(probe))[0]
        want = np.asarray(probe.sum(0))
        err = float(np.abs(got - want).max() /
                    (np.abs(want).max() + 1e-30))
        on_log(f"planner: executed-schedule self-check rel err {err:.2e}")
        assert err < 1e-5, "executed TP schedule disagrees with psum"
        # The self-check already executed the decode plan — time it and
        # feed the measurement into the planner's online loop (DESIGN.md
        # §10): serving deployments accumulate decode-plan samples the
        # same way training accumulates sync probes, and sustained drift
        # refits the level class and hot-swaps the schedule.
        import time
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(f(probe))
            ts.append(time.perf_counter() - t0)
        measured = sorted(ts)[len(ts) // 2]
        try:
            # no predicted= override: tp_exec.predicted_time is priced
            # at the geometric cache-bucket size (up to ~2x the decode
            # payload); observe's default re-prices at the exact
            # executed size so the residual carries no constant
            # bucket-ratio bias
            obs = default_service().observe(
                "root_sw", n_dev, float(sc.batch * cfg.d_model), measured,
                key=tp_exec.key)
            on_log(f"planner: observed decode plan {measured * 1e3:.3f} "
                   f"ms (predicted {obs['predicted'] * 1e3:.3f} ms, "
                   f"drift {obs['drift']:.2f}"
                   + (", refit" if obs["refit"] else "") + ")")
        except Exception as e:   # advisory measurement — never fail serve
            on_log(f"planner: decode observation skipped ({e!r})")
    elif n_dev == 1:
        on_log("planner: single device, no decode collective needed")
    key = jax.random.PRNGKey(sc.seed)
    params = api.init_params(key)

    prompts = jax.random.randint(
        jax.random.fold_in(key, 1), (sc.batch, sc.prompt_len), 0, cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch = {"embeds": jax.random.normal(
            key, (sc.batch, sc.prompt_len, cfg.d_model), jnp.bfloat16),
            "mrope_positions": jnp.tile(
                jnp.arange(sc.prompt_len, dtype=jnp.int32)[None, None],
                (3, sc.batch, 1))}
    elif cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (sc.batch, 32, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(lambda p, b: api.prefill(p, b, cache_len=sc.cache_len))
    decode = jax.jit(api.decode_step)

    tracer = default_tracer()
    metrics = default_metrics()
    with tracer.span("serve/prefill", batch=sc.batch,
                     prompt_len=sc.prompt_len):
        logits, cache = prefill(params, batch)
        tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
    metrics.counter("serve_prefill_total", "prefill calls").inc()
    out = [np.asarray(tok)]
    decode_ctr = metrics.counter("serve_decode_steps_total",
                                 "decode steps executed")
    for i in range(sc.max_new - 1):
        with tracer.span("serve/decode", token=i + 1):
            step_batch = {"tokens": tok[:, None]}
            if cfg.family == "vlm":
                emb = jnp.take(params["embed"], tok[:, None], axis=0)
                step_batch = {"embeds": emb}
            logits, cache = decode(params, cache, step_batch)
            tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        decode_ctr.inc()
        out.append(np.asarray(tok))
    gen = np.stack(out, axis=1)
    on_log(f"served batch={sc.batch} prompt={sc.prompt_len} "
           f"new={sc.max_new}: first row {gen[0][:8].tolist()}...")
    if tp_exec is not None:
        from repro.core.lower import guard_schedule
        tp_sched = guard_schedule(tp_exec.schedule)   # memoized wrapper
    else:
        tp_sched = None
    return {"tokens": gen, "tp_exec": tp_exec, "tp_schedule": tp_sched}


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()
    serve(ServeConfig(arch=args.arch, batch=args.batch,
                      max_new=args.max_new))


if __name__ == "__main__":
    main()
