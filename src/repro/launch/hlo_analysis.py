"""Roofline-term extraction from compiled dry-run artifacts.

XLA's HloCostAnalysis (exposed as compiled.cost_analysis()) counts each
computation ONCE — a lax.scan over 64 layers contributes its body a single
time, which under-counts FLOPs/bytes by ~L×. We therefore parse the
optimized HLO text ourselves and weight every while-loop body by its trip
count (XLA annotates `backend_config={"known_trip_count":{"n":...}}` on
while ops; fall back to the loop-condition constant).

Per-module accounting (per device, SPMD):
  * FLOPs      — 2·prod(result)·prod(contracting dims) per dot
                 (convolutions are not used by these models);
  * HBM bytes  — Σ (operand + result bytes) over top-level compute ops;
    fusions count once at the call site (a fusion is one HBM pass), their
    internals contribute FLOPs only;
  * collective bytes — actual WIRE bytes per device, matching the
    GenModel planner's convention (core.cost_model.family_wire_bytes):
    all-reduce moves 2(n-1)/n·M, reduce-scatter / all-gather /
    all-to-all move (n-1)/n·M, collective-permute moves M — where M is
    the payload (operand bytes; the gathered RESULT bytes for
    all-gather) and n the replica-group size parsed from the
    instruction's `replica_groups`. When the group size cannot be
    determined (`replica_groups={}` = all devices) the asymptotic
    (n-1)/n → 1 factors apply. Raw payloads are kept alongside in
    `ModuleStats.coll_payload_by_kind` so `mix_from_stats` can hand the
    whole-step planner per-family payload sizes, × trip counts.

Roofline terms (TPU v5e-class constants):
  compute   = FLOPs_total / (chips × 197 TFLOP/s)
  memory    = bytes_total / (chips × 819 GB/s)
  collective= coll_bytes_total / (chips × 50 GB/s)
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any

from repro.core.cost_model import family_wire_bytes

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per-chip usable per direction)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops that don't touch HBM (metadata / aliasing / control)
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    "custom-call", "copy-start", "copy-done", "rng-bit-generator",
}

# ops whose HBM traffic we count (operands + result). Standalone
# elementwise ops (convert/add/multiply/exp/...) are *excluded*: the CPU
# backend leaves them unfused where TPU's XLA would fuse them into the
# producer — counting them would inflate the memory term with
# CPU-lowering artifacts. Their traffic is approximated by the
# producer/consumer boundary ops below.
_HBM_OPS = {
    "fusion", "dot", "convolution", "reduce", "reduce-window", "sort",
    "gather", "scatter", "dynamic-slice", "dynamic-update-slice",
    "concatenate", "pad", "slice", "reverse", "transpose", "copy",
    "select-and-scatter", "cholesky", "triangular-solve", "fft",
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    """Dims of the FIRST array shape in the string."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    operands: list[str]
    attrs: str
    line: str


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_OP_RE = re.compile(r"^\s*([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(")


def _parse_instr_line(line: str) -> tuple[str, str, str, str] | None:
    """'%x = <type> op(<rest>' → (name, type, op, rest) with balanced-paren
    type scanning (tuple types contain '=' in /*index=k*/ comments)."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    if i < n and line[i] == "(":          # tuple type
        depth = 0
        j = i
        while j < n:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        rtype = line[i:j + 1]
        i = j + 1
    else:                                  # scalar/array type token
        m2 = re.match(r"[\w\[\]\{\},\d]+", line[i:])
        if not m2:
            return None
        rtype = m2.group(0)
        i += m2.end()
    m3 = _OP_RE.match(line[i:])
    if not m3:
        return None
    return name, rtype, m3.group(1), line[i + m3.end():]


def _split_operands(args: str) -> list[str]:
    """Operand names from the call-paren contents (up to matching paren)."""
    depth = 0
    out = []
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                args = args[:i]
                break
            depth -= 1
    return re.findall(r"%([\w\.\-]+)", args)


class Computation:
    def __init__(self, name: str, header: str):
        self.name = name
        self.header = header
        self.params: dict[str, str] = {}      # param name -> type str
        self.instrs: list[Instr] = []
        self.types: dict[str, str] = {}       # instr/param name -> type
        # parse signature params: "(x: f32[2,3], y: (s32[], f32[4]))"
        sig = header[header.index("("):]
        for m in re.finditer(r"([\w\.\-]+):\s*([^,()]+(?:\([^)]*\))?[\w\[\]\{\},/\* ]*)",
                             sig):
            pass  # simple splitting below is more robust
        # robust: split on top-level commas inside the first paren group
        depth = 0
        start = header.index("(") + 1
        buf = ""
        groups = []
        for ch in header[start:]:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    groups.append(buf)
                    break
                depth -= 1
            elif ch == "," and depth == 0:
                groups.append(buf)
                buf = ""
                continue
            buf += ch
        for g in groups:
            if ":" in g:
                pname, ptype = g.split(":", 1)
                self.params[pname.strip().lstrip("%")] = ptype.strip()

    def add(self, line: str) -> None:
        parsed = _parse_instr_line(line)
        if parsed is None:
            return
        name, rtype, op, rest = parsed
        ops = _split_operands(rest)
        self.instrs.append(Instr(name, rtype, op, ops, rest, line))
        self.types[name] = rtype

    def type_of(self, operand: str) -> str:
        if operand in self.types:
            return self.types[operand]
        if operand in self.params:
            return self.params[operand]
        return ""

    def operand_bytes(self, ins: Instr) -> int:
        return sum(_shape_bytes(self.type_of(o)) for o in ins.operands)


def parse_module(hlo: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(2), line)
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
        else:
            if line.strip() == "}":
                cur = None
            else:
                # parameters also appear as instructions inside the body
                pm = re.match(r"^\s*%([\w\.\-]+)\s*=\s*(\S+)\s+parameter\(",
                              line)
                if pm:
                    cur.types[pm.group(1)] = pm.group(2)
                cur.add(line)
    if not entry and comps:
        entry = max(comps, key=lambda n: len(comps[n].instrs))
    return comps, entry


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')

# replica_groups={{0,1,2,3},{4,5,6,7}} — explicit list-of-lists form
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
# replica_groups=[2,4]<=[8] — iota form, shape (num_groups, group_size)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([\d,]+)\]<=")


def _group_size(line: str) -> int:
    """Replica-group size of a collective instruction, 0 if unknown
    (`replica_groups={}` means one group spanning every device)."""
    m = _GROUPS_RE.search(line)
    if m:
        return len([d for d in m.group(1).split(",") if d])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        dims = [int(d) for d in m.group(1).split(",") if d]
        if dims:
            return dims[-1]
    return 0


def _wire_bytes(kind: str, n: int, payload: float) -> float:
    """Per-device wire bytes for `payload` bytes of collective `kind`
    over an n-member group; n == 0 (unknown size) uses the asymptotic
    (n-1)/n → 1 factors so the estimate stays an upper bound."""
    if n > 0:
        return family_wire_bytes(kind, n, payload)
    if kind == "all-reduce":
        return 2.0 * payload
    return float(payload)


def _trip_count(ins: Instr, comps: dict[str, Computation]) -> int:
    m = _TRIP_RE.search(ins.line)
    if m:
        return int(m.group(1))
    cm = re.search(r"condition=%?([\w\.\-]+)", ins.line)
    if cm and cm.group(1) in comps:
        consts = []
        for i2 in comps[cm.group(1)].instrs:
            for c in re.finditer(r"constant\((\d+)\)", i2.line):
                consts.append(int(c.group(1)))
        if consts:
            return max(consts)
    return 1


def _dot_flops(comp: Computation, ins: Instr) -> float:
    rdims = _shape_dims(ins.result_type)
    out = 1
    for d in rdims:
        out *= d
    lhs_t = comp.type_of(ins.operands[0]) if ins.operands else ""
    ldims = _shape_dims(lhs_t)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    contract = 1
    if m and ldims:
        for ax in m.group(1).split(","):
            if ax:
                ax = int(ax)
                if ax < len(ldims):
                    contract *= ldims[ax]
    return 2.0 * out * contract


@dataclasses.dataclass
class ModuleStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: dict[str, int] = dataclasses.field(default_factory=dict)
    # raw payload bytes (the planner's M) — wire bytes live in coll_by_kind
    coll_payload_by_kind: dict[str, float] = dataclasses.field(
        default_factory=dict)

    def add_coll(self, kind: str, b: float, n: int = 1,
                 payload: float | None = None) -> None:
        self.coll_bytes += b
        self.coll_by_kind[kind] = self.coll_by_kind.get(kind, 0.0) + b
        self.coll_counts[kind] = self.coll_counts.get(kind, 0) + n
        self.coll_payload_by_kind[kind] = \
            self.coll_payload_by_kind.get(kind, 0.0) \
            + (b if payload is None else payload)


def analyze_hlo(hlo: str, breakdown: dict | None = None) -> ModuleStats:
    """breakdown (optional): dict filled with per-computation
    (direct_bytes, total_multiplied_bytes, trips_seen) for debugging."""
    comps, entry = parse_module(hlo)
    stats = ModuleStats()
    # memoized per-computation totals (flops, bytes, coll...) then weight
    memo: dict[tuple[str, bool], ModuleStats] = {}

    def visit(name: str, in_fusion: bool, depth: int = 0) -> ModuleStats:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        s = ModuleStats()
        if comp is None or depth > 64:
            return s
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                cm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                trips = _trip_count(ins, comps)
                if breakdown is not None and cm:
                    breakdown.setdefault("whiles", []).append(
                        (name, cm.group(1), trips))
                if cm:
                    sub = visit(cm.group(1), False, depth + 1)
                    s.flops += sub.flops * trips
                    s.hbm_bytes += sub.hbm_bytes * trips
                    s.coll_bytes += sub.coll_bytes * trips
                    for k, v in sub.coll_by_kind.items():
                        s.coll_by_kind[k] = s.coll_by_kind.get(k, 0) \
                            + v * trips
                    for k, v in sub.coll_counts.items():
                        s.coll_counts[k] = s.coll_counts.get(k, 0) \
                            + v * trips
                    for k, v in sub.coll_payload_by_kind.items():
                        s.coll_payload_by_kind[k] = \
                            s.coll_payload_by_kind.get(k, 0.0) + v * trips
                continue
            if op == "fusion":
                fm = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                if fm:
                    sub = visit(fm.group(1), True, depth + 1)
                    s.flops += sub.flops            # fusion: flops only
                if not in_fusion:
                    s.hbm_bytes += comp.operand_bytes(ins) \
                        + _shape_bytes(ins.result_type)
                continue
            if op == "conditional" or op == "call":
                for cm in re.finditer(r"(?:branch_computations=\{([^}]*)\}"
                                      r"|to_apply=%?([\w\.\-]+))", ins.line):
                    names = (cm.group(1) or cm.group(2) or "")
                    for nm in re.findall(r"%?([\w\.\-]+)", names):
                        if nm in comps:
                            sub = visit(nm, in_fusion, depth + 1)
                            s.flops += sub.flops
                            s.hbm_bytes += sub.hbm_bytes
                            s.coll_bytes += sub.coll_bytes
                            for k, v in sub.coll_by_kind.items():
                                s.coll_by_kind[k] = \
                                    s.coll_by_kind.get(k, 0.0) + v
                            for k, v in sub.coll_counts.items():
                                s.coll_counts[k] = \
                                    s.coll_counts.get(k, 0) + v
                            for k, v in sub.coll_payload_by_kind.items():
                                s.coll_payload_by_kind[k] = \
                                    s.coll_payload_by_kind.get(k, 0.0) + v
                continue
            base = op.replace("-start", "")
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                # payload M: full operand bytes, except all-gather whose
                # natural payload is the gathered RESULT
                if base == "all-gather":
                    payload = float(_shape_bytes(ins.result_type))
                else:
                    payload = float(comp.operand_bytes(ins))
                ng = _group_size(ins.line)
                s.add_coll(base, _wire_bytes(base, ng, payload),
                           payload=payload)
                if not in_fusion:
                    s.hbm_bytes += comp.operand_bytes(ins) \
                        + _shape_bytes(ins.result_type)
                continue
            if op == "dot":
                s.flops += _dot_flops(comp, ins)
                if not in_fusion:
                    s.hbm_bytes += comp.operand_bytes(ins) \
                        + _shape_bytes(ins.result_type)
                continue
            if op in _FREE_OPS:
                continue
            # data-movement / reduction ops count; standalone elementwise
            # ops are treated as fused away (see _HBM_OPS note)
            if not in_fusion and op in _HBM_OPS:
                s.hbm_bytes += comp.operand_bytes(ins) \
                    + _shape_bytes(ins.result_type)
        memo[key] = s
        return s

    top = visit(entry, False)
    return top


# HLO op spelling → plan-IR family name (core.plans.FAMILIES)
_KIND_TO_FAMILY = {
    "all-reduce": "allreduce",
    "reduce-scatter": "reduce_scatter",
    "all-gather": "allgather",
    "all-to-all": "all_to_all",
    "collective-permute": "p2p",
}


def mix_from_stats(stats: ModuleStats, dsize: int = 4) -> dict:
    """Collective mix for `PlannerService.get_step_plan`: per family, the
    call count and the MEAN per-call payload in element units (raw
    payload bytes / count / dsize) — the planner re-prices wire bytes
    itself from the payload, so the wire-convention fix never double
    applies."""
    mix: dict[str, dict[str, float]] = {}
    for kind, cnt in stats.coll_counts.items():
        fam = _KIND_TO_FAMILY.get(kind)
        if fam is None or cnt <= 0:
            continue
        payload = stats.coll_payload_by_kind.get(
            kind, stats.coll_by_kind.get(kind, 0.0))
        mix[fam] = {"count": int(cnt),
                    "size_floats": float(payload) / cnt / dsize}
    return mix


@dataclasses.dataclass
class Roofline:
    flops: float                 # total FLOPs across all chips
    hbm_bytes: float             # total HBM bytes across all chips
    coll_bytes: float            # total collective bytes across all chips
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    coll_by_kind: dict[str, float]
    model_flops: float = 0.0

    @property
    def bound(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """model-FLOPs time at peak vs the dominant-term time (an MFU-style
        score derivable without wall clocks)."""
        if self.bound <= 0:
            return 0.0
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.bound


def roofline_from_stats(per_device: ModuleStats, chips: int,
                        model_flops: float = 0.0) -> Roofline:
    """per_device: stats of ONE SPMD partition's module; totals are ×chips
    (so per-chip rates divide back out)."""
    flops = per_device.flops * chips
    hbm = per_device.hbm_bytes * chips
    cb = per_device.coll_bytes * chips
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = hbm / (chips * HBM_BW)
    coll_s = cb / (chips * ICI_BW)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=cb, chips=chips,
                    compute_s=compute_s, memory_s=memory_s,
                    collective_s=coll_s, dominant=dominant,
                    coll_by_kind={k: v * chips
                                  for k, v in per_device.coll_by_kind.items()},
                    model_flops=model_flops)


def model_flops_train(cfg, tokens: int) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) per the assignment."""
    return 6.0 * cfg.active_params_count() * tokens


def model_flops_forward(cfg, tokens: int) -> float:
    return 2.0 * cfg.active_params_count() * tokens
