"""Production mesh builders.

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run entrypoint sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before importing jax.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally available devices (tests/examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
