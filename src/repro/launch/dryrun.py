import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first init, and the production meshes need 512 placeholders.

import argparse          # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, get_config, supported_shapes  # noqa: E402
from repro.models.config import SHAPES  # noqa: E402
from repro.models.registry import build  # noqa: E402
from repro.optim import AdamWConfig, adamw_init  # noqa: E402
from . import hlo_analysis as ha  # noqa: E402
from . import sharding as shr  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .train import make_train_step  # noqa: E402

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production mesh, print memory/cost analysis, and extract the
roofline terms (launch.hlo_analysis). No arrays are ever allocated — all
inputs are ShapeDtypeStructs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
        --shape train_4k [--multi-pod] [--json out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    api = build(get_config(arch))
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return api.train_specs(shape)
    if shape.kind == "prefill":
        return api.prefill_specs(shape)
    return api.decode_specs(shape)


def _spec_tree(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _seqpar_hook(mesh):
    """Sequence-parallel residual stream: (B, T, D) activations carry
    (dp-batch, model-sequence) sharding between blocks, so the TP
    boundary collectives become reduce-scatter + all-gather instead of
    all-reduce (Megatron-SP) — halves TP collective bytes and shards the
    norms."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = tuple(a for a in mesh.axis_names if a != "model")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dpn = 1
    for a in dp:
        dpn *= sizes[a]
    model = sizes.get("model", 1)

    def hook(x):
        if x.ndim == 3 and x.shape[0] % dpn == 0 and x.shape[0] > 1 \
                and x.shape[1] % model == 0:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, "model", None)))
        if x.ndim >= 2 and x.shape[0] % dpn == 0 and x.shape[0] > 1:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, *([None] * (x.ndim - 1)))))
        return x

    return hook


def lower_cell(arch: str, shape_name: str, mesh, *,
               compile_: bool = True,
               variants: tuple[str, ...] = ()) -> dict:
    """variants — §Perf hillclimb knobs, applied on top of the baseline:
      kvblock=N  flash-in-XLA attention with N-wide KV blocks
      zero1      params replicated over DP, optimizer state sharded
      seqpar     sequence-parallel residual stream (T over 'model')
    """
    import dataclasses as _dc
    from repro.models import actsharding
    cfg = get_config(arch)
    fsdp = True
    hook = actsharding.batch_dp_hook(mesh)
    for v in variants:
        if v.startswith("kvblock="):
            cfg = _dc.replace(cfg, attn_kv_block=int(v.split("=")[1]))
        elif v.startswith("moegroups="):
            cfg = _dc.replace(cfg, moe_groups=int(v.split("=")[1]))
        elif v == "moelocal":
            cfg = _dc.replace(cfg, moe_local=True)
        elif v == "zero1":
            fsdp = False
        elif v == "seqpar":
            hook = _seqpar_hook(mesh)
        elif v:
            raise ValueError(f"unknown variant {v!r}")
    api = build(cfg)
    shape = SHAPES[shape_name]
    chips = mesh.devices.size
    actsharding.set_hook(hook, mesh)
    t0 = time.perf_counter()

    if shape.kind == "train":
        batch_sds = api.train_specs(shape)
        state_sds = jax.eval_shape(lambda: {
            "params": api.init_params(jax.random.PRNGKey(0)),
            "opt": adamw_init(api.init_params(jax.random.PRNGKey(0)))})
        jitted, *_ = make_train_step(api, mesh, AdamWConfig(), fsdp=fsdp,
                                     act_hook=hook)
        with mesh:
            lowered = jitted(state_sds, batch_sds).lower(state_sds,
                                                         batch_sds)
        mf = ha.model_flops_train(cfg, shape.global_batch * shape.seq_len)
    elif shape.kind == "prefill":
        batch_sds = api.prefill_specs(shape)
        params_sds = api.params_spec()
        p_spec = shr.params_specs(params_sds, mesh, fsdp=fsdp)
        b_spec = shr.batch_specs(batch_sds, mesh)

        def fn(params, batch):
            return api.prefill(params, batch, cache_len=shape.seq_len)

        with mesh:
            lowered = jax.jit(
                fn,
                in_shardings=(shr.to_named(p_spec, mesh),
                              shr.to_named(b_spec, mesh)),
            ).lower(params_sds, batch_sds)
        mf = ha.model_flops_forward(cfg, shape.global_batch * shape.seq_len)
    else:  # decode
        specs = input_specs(arch, shape_name)
        batch_sds, cache_sds = specs["batch"], specs["cache"]
        params_sds = api.params_spec()
        p_spec = shr.params_specs(params_sds, mesh, fsdp=fsdp)
        b_spec = shr.batch_specs(batch_sds, mesh)
        c_spec = shr.cache_specs(cache_sds, mesh)

        def fn(params, cache, batch):
            return api.decode_step(params, cache, batch)

        with mesh:
            lowered = jax.jit(
                fn,
                in_shardings=(shr.to_named(p_spec, mesh),
                              shr.to_named(c_spec, mesh),
                              shr.to_named(b_spec, mesh)),
                out_shardings=(None, shr.to_named(c_spec, mesh)),
                donate_argnums=(1,),
            ).lower(params_sds, cache_sds, batch_sds)
        mf = ha.model_flops_forward(cfg, shape.global_batch)

    result = {"arch": arch, "shape": shape_name, "chips": chips,
              "kind": shape.kind, "lower_s": time.perf_counter() - t0}
    if not compile_:
        return result

    t1 = time.perf_counter()
    compiled = lowered.compile()
    result["compile_s"] = time.perf_counter() - t1

    mem = compiled.memory_analysis()
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                result[attr] = int(v)
        result["bytes_per_device"] = (
            result.get("argument_size_in_bytes", 0)
            + result.get("temp_size_in_bytes", 0))
    cost = compiled.cost_analysis() or {}
    stats = ha.analyze_hlo(compiled.as_text())
    rl = ha.roofline_from_stats(stats, chips, model_flops=mf)
    result.update({
        "hlo_flops": rl.flops,
        "hlo_bytes": rl.hbm_bytes,
        "coll_bytes": rl.coll_bytes,
        "coll_by_kind": rl.coll_by_kind,
        "coll_counts": stats.coll_counts,
        "xla_cost_flops": float(cost.get("flops", 0.0)),
        "xla_cost_bytes": float(cost.get("bytes accessed", 0.0)),
        "compute_s": rl.compute_s,
        "memory_s": rl.memory_s,
        "collective_s": rl.collective_s,
        "dominant": rl.dominant,
        "model_flops": mf,
        "useful_ratio": rl.useful_ratio,
        "roofline_fraction": rl.roofline_fraction,
    })
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default=None, help="write results JSON here")
    ap.add_argument("--include-skips", action="store_true")
    ap.add_argument("--variants", default="",
                    help="comma-separated §Perf knobs: kvblock=N, zero1, "
                    "seqpar")
    args = ap.parse_args()
    variants = tuple(v for v in args.variants.split(",") if v)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"({mesh.devices.size} chips)")

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch.replace("-", "_"), args.shape))

    results = []
    failed = 0
    for arch, shape in cells:
        sup = supported_shapes(arch)
        if shape not in sup:
            results.append({"arch": arch, "shape": shape,
                            "skipped": "unsupported (DESIGN.md "
                            "§Arch-applicability)"})
            print(f"[skip] {arch} × {shape} — documented skip")
            continue
        try:
            r = lower_cell(arch, shape, mesh, variants=variants)
            results.append(r)
            print(f"[ ok ] {arch} × {shape}: "
                  f"flops={r['hlo_flops']:.3e} bytes={r['hlo_bytes']:.3e} "
                  f"coll={r['coll_bytes']:.3e} dom={r['dominant']} "
                  f"t_comp={r['compute_s']*1e3:.2f}ms "
                  f"t_mem={r['memory_s']*1e3:.2f}ms "
                  f"t_coll={r['collective_s']*1e3:.2f}ms "
                  f"(compile {r['compile_s']:.1f}s)")
        except Exception as e:
            failed += 1
            results.append({"arch": arch, "shape": shape,
                            "error": repr(e)})
            print(f"[FAIL] {arch} × {shape}: {e!r}")
            traceback.print_exc()

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"multi_pod": args.multi_pod, "results": results}, f,
                      indent=1)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
