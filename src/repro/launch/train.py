"""Training drivers.

Two engines:

* **auto** — jax.jit + NamedSharding (FSDP × TP × pod-DP). XLA SPMD inserts
  every collective. This is the baseline engine every dry-run cell lowers
  with.
* **manual** — shard_map over the DP axes ('pod', 'data'); parameters are
  ZeRO-3 sharded (flat shards per leaf), gathered with a *plan-selected*
  AllGather and gradients reduced with a *plan-selected* ReduceScatter —
  ring / rhd / cps / hcps per core.sync's GenModel pricing, or, with
  sync="plan", the GenTree Plan IR itself lowered to a compiled schedule
  (core.lower, DESIGN.md §8) and executed round-for-round. This is the
  paper's technique as a first-class training feature: GenTree decides the
  collective schedule, the engine executes it.

`python -m repro.launch.train --arch <id> --steps N` runs a reduced-config
training loop on the local device (examples/tests); full-size configs are
exercised via launch.dryrun.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import collectives
from repro.core.sync import AxisPlan, SyncConfig, plan_axes_gentree
from repro.models.registry import ModelAPI
from repro.optim import AdamWConfig, adamw_init, adamw_update
from . import sharding as shr
from .mesh import dp_axes, axis_sizes


# ---------------------------------------------------------------------------
# auto engine (pjit)
# ---------------------------------------------------------------------------
def make_train_step(api: ModelAPI, mesh: Mesh,
                    opt_cfg: AdamWConfig = AdamWConfig(), *,
                    donate: bool = True, fsdp: bool = True,
                    act_hook=None):
    """Returns (jitted_step, state_shardings_fn, batch_shardings_fn).
    fsdp=False → ZeRO-1 (params replicated over DP, moments sharded)."""
    from repro.models import actsharding

    def step(state, batch):
        actsharding.set_hook(act_hook or actsharding.batch_dp_hook(mesh),
                             mesh)
        loss, grads = jax.value_and_grad(
            lambda p: api.loss_fn(p, batch))(state["params"])
        params, opt, gnorm = adamw_update(state["params"], grads,
                                          state["opt"], opt_cfg)
        return ({"params": params, "opt": opt},
                {"loss": loss, "gnorm": gnorm})

    def state_shardings(state_shape):
        p_spec = shr.params_specs(state_shape["params"], mesh, fsdp=fsdp)
        return shr.to_named(
            {"params": p_spec,
             "opt": shr.opt_specs(state_shape["opt"], p_spec, mesh)},
            mesh)

    def batch_shardings(batch_shape):
        return shr.to_named(shr.batch_specs(batch_shape, mesh), mesh)

    def jitted(state_shape, batch_shape):
        ss = state_shardings(state_shape)
        bs = batch_shardings(batch_shape)
        ms = shr.to_named({"loss": P(), "gnorm": P()}, mesh)
        return jax.jit(step, in_shardings=(ss, bs),
                       out_shardings=(ss, ms),
                       donate_argnums=(0,) if donate else ())

    return jitted, state_shardings, batch_shardings


# ---------------------------------------------------------------------------
# manual engine (shard_map, ZeRO-3 with plan-selected collectives)
# ---------------------------------------------------------------------------
def _flat_shard(x: jax.Array, n: int, idx: jax.Array) -> jax.Array:
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return jax.lax.dynamic_slice_in_dim(flat, idx * (flat.size // n),
                                        flat.size // n)


def shard_params_zero3(params: Any, mesh: Mesh) -> Any:
    """Host-side: split every leaf into flat per-DP-rank shards, placed with
    P(dp) on a leading shard axis."""
    dp = dp_axes(mesh)
    sizes = axis_sizes(mesh)
    n = 1
    for a in dp:
        n *= sizes[a]

    def split(x):
        flat = jnp.asarray(x).reshape(-1)
        pad = (-flat.size) % n
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
        out = flat.reshape(n, -1)
        return jax.device_put(out, NamedSharding(mesh, P(dp, None)))

    return jax.tree.map(split, params)


def _gather_leaf(shard: jax.Array, shape, dtype, plans: Sequence[AxisPlan]):
    flat = shard
    for pl in plans:
        # collectives.all_gather inverts _scatter_leaf's reduce_scatter
        # per strategy — including the hcps un-reorder back to native
        # holder order (gathering with all_gather_hcps directly permutes
        # the result, since reduce_scatter hands back natural shards)
        flat = collectives.all_gather(flat, pl.axis, pl.strategy,
                                      factors=pl.factors,
                                      schedule=pl.schedule)
    n = 1
    for s in shape:
        n *= s
    return flat.reshape(-1)[:n].reshape(shape).astype(dtype)


def _scatter_leaf(full: jax.Array, plans: Sequence[AxisPlan]):
    flat = full.reshape(-1)
    for pl in reversed(plans):
        flat = collectives.reduce_scatter(flat, pl.axis, pl.strategy,
                                          factors=pl.factors,
                                          schedule=pl.schedule)
    return flat


def make_manual_train_step(api: ModelAPI, mesh: Mesh,
                           opt_cfg: AdamWConfig = AdamWConfig(), *,
                           sync: SyncConfig = SyncConfig(strategy="gentree"),
                           planner=None):
    """ZeRO-3 shard_map engine. Parameter AllGather and gradient
    ReduceScatter run the GenModel-selected plan per mesh level (intra-pod
    first, cross-pod second — the paper's hierarchical structure).

    Plan lookups route through the PlannerService (repro.planner): plans
    are resolved once at engine-build (trace) time, and the fingerprinted,
    size-bucketed cache pays off across engine rebuilds and — with
    $REPRO_PLAN_CACHE set — across process restarts, which skip the
    GenModel search entirely. Pass `planner` to use a calibrated or
    skew-aware service instead of the process-wide default."""
    dp = dp_axes(mesh)
    sizes = axis_sizes(mesh)
    axes = [(a, sizes[a]) for a in dp if sizes[a] > 1]
    ndp = 1
    for _, s in axes:
        ndp *= s
    shapes = api.params_spec()
    leaf_shapes = jax.tree.map(lambda l: (l.shape, l.dtype), shapes,
                               is_leaf=lambda x: hasattr(x, "shape"))

    def plans_for(size_floats: float) -> list[AxisPlan]:
        from repro.core.sync import resolve_axis_plans
        if sync.strategy == "auto":
            return [AxisPlan(a, "psum") for a, _ in axes]
        if planner is not None and sync.strategy == "gentree":
            return planner.get_axis_plans(axes, size_floats,
                                          params=sync.params)
        if planner is not None and sync.strategy == "plan":
            from repro.core.sync import axis_level
            out = []
            for i, (a, n) in enumerate(axes):
                resp = planner.get_axis_executable(
                    a, n, size_floats, level=axis_level(i),
                    params=sync.params)
                sched = resp.schedule
                if getattr(sync, "guard", True):
                    from repro.core.lower import guard_schedule
                    sched = guard_schedule(
                        sched,
                        telemetry=getattr(planner, "telemetry", None))
                out.append(AxisPlan(a, "plan", schedule=sched,
                                    predicted=resp.predicted_time))
            return out
        # gentree/plan route through the process-wide PlannerService inside
        # resolve_axis_plans; only an explicit override needs handling here.
        return resolve_axis_plans(axes, sync, size_floats)

    flat_sd, sd_treedef = jax.tree.flatten(
        jax.tree.map(lambda l: (tuple(l.shape), l.dtype), shapes,
                     is_leaf=lambda x: hasattr(x, "shape")),
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple))
    # f32-equivalent size of the full parameter set (total bytes / 4), so
    # bf16/f16 models are priced at their real data volume rather than at
    # raw element counts in float32 units
    total_f32_equiv = sum(
        int(math.prod(sd[0])) * jnp.dtype(sd[1]).itemsize
        for sd in flat_sd) / 4.0

    def bucket_plan_for():
        """Bucketed ZeRO-3 halves (DESIGN.md §9): one schedule launch per
        dtype-homogeneous bucket instead of per leaf, bucket size the
        GenModel sweep argmin. Single-DP-axis layout only (the bucket's
        row layout must match the host-side shard split); multi-axis
        meshes and schedules without canonical RS/AG halves fall back to
        the per-leaf path."""
        if (sync.strategy != "plan" or sync.bucket_bytes == 0
                or len(axes) != 1):
            return None
        svc = planner
        if svc is None:
            from repro.planner.service import default_service
            svc = default_service()
        from repro.core.bucketing import BucketConfig
        from repro.core.lower import LoweringError
        try:
            bp = svc.get_bucket_plan(
                axes, total_f32_equiv or 1.0, params=sync.params,
                config=BucketConfig(
                    bucket_bytes=sync.bucket_bytes,
                    pipeline=sync.pipeline,
                    precision=getattr(sync, "precision", None),
                    tolerance=getattr(sync, "tolerance", None)))
        except LoweringError:
            return None
        cs = bp.axis_plans[0].schedule if bp.axis_plans else None
        if cs is None or not cs.blocks_per_shard:
            return None
        if getattr(sync, "guard", True):
            # the zero3 bucketed halves launch plan.schedule directly,
            # so the guard (DESIGN.md §12) must wrap here too — the
            # memoized wrapper keeps demotion sticky across retraces
            import dataclasses as _dc
            from repro.core.lower import guard_schedule
            tele = getattr(svc, "telemetry", None)
            bp = _dc.replace(bp, axis_plans=[
                _dc.replace(pl, schedule=guard_schedule(pl.schedule,
                                                        telemetry=tele))
                if pl.schedule is not None else pl
                for pl in bp.axis_plans])
        return bp

    # Expert-parallel MoE (ISSUE 9 tentpole): when the model routes
    # experts and they shard evenly over the leaf DP axis, the MoE layer
    # dispatches with AllToAll inside this engine's shard_map — executed
    # from a lowered family="all_to_all" plan under strategy="plan"
    # (guarded like every other planned collective), lax.all_to_all
    # otherwise.
    ep_axis, ep_n = (axes[0] if axes else (None, 1))
    use_ep = (getattr(api.cfg, "n_experts", 0) > 1 and ep_axis is not None
              and ep_n > 1 and api.cfg.n_experts % ep_n == 0)
    ep_sched = None
    if use_ep and sync.strategy == "plan":
        svc = planner
        if svc is None:
            from repro.planner.service import default_service
            svc = default_service()
        try:
            resp = svc.get_family_executable(
                "all_to_all", ep_axis, ep_n, total_f32_equiv or 1.0,
                params=sync.params)
            ep_sched = resp.schedule
            if ep_sched is not None and getattr(sync, "guard", True):
                from repro.core.lower import guard_schedule
                ep_sched = guard_schedule(
                    ep_sched, telemetry=getattr(svc, "telemetry", None))
        except Exception:
            ep_sched = None           # lax.all_to_all fallback

    def step(state, batch):
        from repro.models import actsharding
        actsharding.set_hook(None)    # shard_map bodies are fully manual

        def inner(p_shards, opt, batch_local):
            from repro.core import bucketing
            total_size = sum(
                float(jnp.size(s)) for s in jax.tree.leaves(p_shards)) or 1.0
            bplan = bucket_plan_for()
            plans = None if bplan is not None else plans_for(total_size)

            flat_shards = jax.tree.leaves(p_shards)
            if bplan is not None:
                gathered = bucketing.zero3_gather_bucketed(
                    [s[0] for s in flat_shards], flat_sd,
                    bplan.axis_plans[0], bplan.bucket_bytes, ndp)
            else:
                gathered = [
                    _gather_leaf(s[0], sd[0], sd[1], plans)
                    for s, sd in zip(flat_shards, flat_sd)]
            params = jax.tree.unflatten(jax.tree.structure(p_shards),
                                        gathered)
            if use_ep:
                from repro.core import sync as sync_mod
                with sync_mod.expert_parallel(ep_axis, ep_n, ep_sched):
                    loss, grads = jax.value_and_grad(
                        lambda p: api.loss_fn(p, batch_local, remat=True,
                                              moe_dispatch="ep"))(params)
            else:
                loss, grads = jax.value_and_grad(
                    lambda p: api.loss_fn(p, batch_local,
                                          remat=True))(params)
            # mean over DP shards happens inside the reduce; rescale
            if bplan is not None:
                rows = bucketing.zero3_scatter_bucketed(
                    jax.tree.leaves(grads), bplan.axis_plans[0],
                    bplan.bucket_bytes, ndp,
                    reverse=getattr(sync, "backward_overlap", False))
                g_shards = jax.tree.unflatten(
                    jax.tree.structure(grads),
                    [(r / ndp)[None] for r in rows])
            else:
                g_shards = jax.tree.map(
                    lambda g: (_scatter_leaf(g, plans) / ndp)[None], grads)
            loss = jax.lax.pmean(loss, tuple(a for a, _ in axes))
            new_p, new_o, gn = adamw_update(p_shards, g_shards, opt, opt_cfg)
            gn = jax.lax.pmean(gn, tuple(a for a, _ in axes))
            return new_p, new_o, loss, gn

        from repro.core.compat import shard_map
        spec_shard = jax.tree.map(lambda _: P(dp, None), state["params"])
        spec_opt = {"m": spec_shard, "v": spec_shard, "step": P()}
        bspec = shr.batch_specs(batch, mesh)
        new_p, new_o, loss, gn = shard_map(
            inner, mesh=mesh,
            in_specs=(spec_shard, spec_opt, bspec),
            out_specs=(spec_shard, spec_opt, P(), P()),
            check_vma=False)(state["params"], state["opt"], batch)
        return ({"params": new_p, "opt": new_o},
                {"loss": loss, "gnorm": gn})

    return jax.jit(step, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# driver (reduced-config local training; examples import run_training)
# ---------------------------------------------------------------------------
def observe_sync_probe(svc, mesh, axes, size_floats, on_log=print, *,
                       repeats: int = 3):
    """Measure each live DP axis's compiled schedule on the real mesh and
    feed the timings into the planner's online loop (DESIGN.md §10).

    The training step is one fused jit program — the collective's wall
    time cannot be carved out of it — so the measurement instrument is a
    *probe*: the axis's lowered `CompiledSchedule` (the exact schedule
    the step executes) runs alone under shard_map on the live mesh, and
    its measured median wall time is paired with the GenModel prediction
    via `PlannerService.observe`. Each axis is probed at TWO sizes (the
    requested size and a quarter of it): the refit trigger refuses a
    rank-deficient fit from one repeated (n, size) point
    (`PlannerService._sample_diversity`), so a train-only deployment
    must deposit size diversity or its accumulated drift could never
    refit. A single run only deposits a handful of samples (below any
    refit policy's `min_samples`), so short smoke runs never perturb
    the pricing basis."""
    import time

    from jax.sharding import PartitionSpec as P

    from repro.core.compat import shard_map
    from repro.core.sync import axis_level

    out = []
    big = max(float(size_floats), 4.0)
    for i, (a, n) in enumerate(axes):
        if n <= 1:
            continue
        for size in (big, big / 4.0):
            try:
                resp = svc.get_axis_executable(a, int(n), size,
                                               level=axis_level(i))
                sched = resp.schedule
                probe = jnp.ones((int(n), max(int(size), 1)), jnp.float32)
                # jitted: an un-jitted shard_map re-traces per call,
                # which would time the tracer instead of the collective
                f = jax.jit(shard_map(
                    lambda v, s=sched, ax=a: s.allreduce(v[0], ax)[None],
                    mesh=mesh, in_specs=P(a), out_specs=P(a)))
                jax.block_until_ready(f(probe))          # warm/compile
                ts = []
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    jax.block_until_ready(f(probe))
                    ts.append(time.perf_counter() - t0)
                measured = sorted(ts)[len(ts) // 2]
                # no predicted= override: the probe size rarely lands on
                # a geometric cache bucket, and resp.predicted_time is
                # priced at the SNAPPED size — observe's default
                # re-prices the plan at the exact executed size, so the
                # residual compares like with like instead of carrying a
                # constant bucket-ratio bias
                obs = svc.observe(axis_level(i), int(n), size, measured,
                                  key=resp.key)
                out.append(obs)
                on_log(f"planner: axis {a} sync probe "
                       f"({int(size)} floats) {measured * 1e3:.3f} ms "
                       f"(predicted {obs['predicted'] * 1e3:.3f} ms, "
                       f"drift {obs['drift']:.2f}"
                       + (", refit" if obs["refit"] else "") + ")")
            except Exception as e:   # advisory — never fail training
                on_log(f"planner: sync probe for axis {a} skipped ({e!r})")
    return out


@dataclasses.dataclass
class TrainConfig:
    arch: str = "stablelm-12b"
    steps: int = 50
    seq_len: int = 128
    global_batch: int = 8
    engine: str = "auto"            # auto | manual
    sync: str = "auto"         # auto|psum|ring|rhd|cps|hcps|gentree|plan
    # backward-overlapped bucket issuance (DESIGN.md §15): reverse-layer
    # readiness order + the planner's merged RS/AG launch when its
    # contended argmin picked "merged"; False restores forward order
    backward_overlap: bool = True
    lr: float = 1e-3
    ckpt_dir: str | None = None
    ckpt_every: int = 25
    seed: int = 0
    log_every: int = 10
    # feed measured sync timings back into the planner's online loop
    # (probe after training; per-step wall times into the telemetry ring)
    observe_sync: bool = True
    # when set, enable the process-wide tracer for the run and export a
    # Chrome-trace JSON (load in chrome://tracing or ui.perfetto.dev) of
    # every recorded span — planner, lowering, bucketing and train steps
    trace_path: str | None = None
    # when set, export the process-wide metrics registry (JSON snapshot +
    # sibling .prom text file) at the end of the run
    metrics_path: str | None = None
    # chaos mode (DESIGN.md §12): a `FaultPlan.parse` spec string (e.g.
    # "seed=7,steps=200,link_degrade=0.01,payload_corrupt=0.05") arms a
    # deterministic fault injector for the run; None defers to any
    # $REPRO_FAULT_PLAN / surrounding FaultInjector context
    fault_plan: str | None = None


def run_training(tc: TrainConfig, mesh: Mesh | None = None,
                 smoke: bool = True, on_log=print) -> dict:
    from repro.configs import get_config
    from repro.data import DataConfig, SyntheticLM
    from repro.models.config import smoke_config
    from repro.models.registry import build
    from repro.checkpoint import CheckpointManager
    from repro.runtime import FaultTolerantLoop

    cfg = get_config(tc.arch)
    if smoke:
        cfg = smoke_config(cfg)
    api = build(cfg)
    mesh = mesh or jax.make_mesh((len(jax.devices()), 1), ("data", "model"))

    dcfg = DataConfig(
        vocab=cfg.vocab, seq_len=tc.seq_len, global_batch=tc.global_batch,
        seed=tc.seed,
        embed_dim=cfg.d_model if cfg.embeds_input else 0,
        frames=32 if cfg.family == "audio" else 0)
    data = SyntheticLM(dcfg)
    opt_cfg = AdamWConfig(lr=tc.lr)

    params = api.init_params(jax.random.PRNGKey(tc.seed))
    state = {"params": params, "opt": adamw_init(params)}

    if tc.engine == "manual":
        state = {"params": shard_params_zero3(state["params"], mesh),
                 "opt": adamw_init(shard_params_zero3(params, mesh))}
        step_fn = make_manual_train_step(
            api, mesh, opt_cfg, sync=SyncConfig(
                strategy=tc.sync,
                backward_overlap=tc.backward_overlap))
    else:
        jitted, ss_fn, bs_fn = make_train_step(api, mesh, opt_cfg)
        b0 = jax.tree.map(jnp.asarray, data.batch_at(0))
        step_fn = jitted(jax.eval_shape(lambda: state),
                         jax.eval_shape(lambda: b0))

    losses = []
    # the process-wide telemetry hub: the same rings the straggler
    # watchdog and the planner's drift detector read (DESIGN.md §10)
    from repro.runtime.telemetry import default_telemetry
    tele = default_telemetry() if tc.observe_sync else None

    from repro.runtime.metrics import default_metrics
    from repro.runtime.trace import default_tracer
    tracer = default_tracer()
    if tc.trace_path:
        tracer.enabled = True
    step_hist = default_metrics().histogram(
        "train_step_seconds", "wall time per training step",
        buckets=(0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 30.0))

    def one_step(state, step):
        import time as _time
        t0 = _time.perf_counter()
        with tracer.span("train/step", step=step):
            batch = jax.tree.map(jnp.asarray, data.batch_at(step))
            state, metrics = step_fn(state, batch)
        step_hist.observe(_time.perf_counter() - t0)
        if step % tc.log_every == 0:
            on_log(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                   f"gnorm {float(metrics['gnorm']):.3f}")
        losses.append(float(metrics["loss"]))
        return state

    import contextlib
    injector = None
    inj_scope = contextlib.nullcontext()
    if tc.fault_plan:
        from repro.runtime.faults import FaultInjector, FaultPlan
        injector = FaultInjector(FaultPlan.parse(tc.fault_plan))
        # entering the scope arms the process-global injector, so
        # GuardedSchedule launches see the payload-corruption events too
        inj_scope = injector
        on_log(f"chaos: armed fault plan {injector.plan.key()} "
               f"({len(injector.plan.events)} events)")
    if tc.ckpt_dir:
        # hand the loop the planner so injected link faults replan
        # through the service's health map (DESIGN.md §12)
        loop_planner = None
        if tc.engine == "manual" and tc.sync in ("gentree", "plan"):
            from repro.planner.service import default_service
            loop_planner = default_service()
        mgr = CheckpointManager(tc.ckpt_dir, keep=2)
        loop = FaultTolerantLoop(one_step, state, mgr,
                                 ckpt_every=tc.ckpt_every,
                                 telemetry=tele,
                                 planner=loop_planner,
                                 injector=injector)
        with inj_scope:
            state = loop.run(tc.steps)
        if injector is not None:
            on_log(f"chaos: injector fired {injector.stats()['fired']}")
    else:
        import time
        with inj_scope:
            for s in range(tc.steps):
                t0 = time.perf_counter()
                state = one_step(state, s)
                if tele is not None:
                    tele.record("train/step", time.perf_counter() - t0)

    if tc.engine == "manual" and tc.sync in ("gentree", "plan"):
        # Plans resolve once at trace time, so a fresh process shows one
        # miss per axis-plan request; hits appear on engine rebuilds and
        # on warm restarts via $REPRO_PLAN_CACHE.
        from repro.planner.service import default_service
        svc = default_service()
        if tc.observe_sync and tc.sync == "plan":
            # close the measurement loop: execute each DP axis's
            # compiled schedule alone on the live mesh and feed the
            # measured timings into the drift detector. The axis list is
            # filtered exactly as make_manual_train_step builds it —
            # size-1 axes dropped BEFORE level indexing — so the probe
            # observes the same Table-5 level class the step priced.
            dp = dp_axes(mesh)
            sizes_by_axis = axis_sizes(mesh)
            live = [(a, sizes_by_axis[a]) for a in dp
                    if sizes_by_axis[a] > 1]
            if live:
                probe_floats = min(
                    sum(float(x.size) for x in
                        jax.tree.leaves(state["params"])) or 1.0,
                    65536.0)
                observe_sync_probe(svc, mesh, live, probe_floats, on_log)
        st = svc.stats()
        cs = st["cache"]
        on_log(f"planner cache: {st['entries']} entries, "
               f"{cs['hits']} hits / {cs['misses']} misses"
               + (f", {cs['disk_loads']} loaded from disk"
                  if cs["disk_loads"] else ""))
        if st["refits"]:
            on_log(f"planner: {len(st['refits'])} online refit(s): "
                   + ", ".join(f"{r['level']} (drift {r['drift']:.2f})"
                               for r in st["refits"]))

    if tc.trace_path:
        tracer.export_chrome(tc.trace_path)
        on_log(f"trace: {len(tracer.spans)} spans -> {tc.trace_path}")
    if tc.metrics_path:
        default_metrics().export(tc.metrics_path)
        on_log(f"metrics -> {tc.metrics_path}")

    return {"state": state, "losses": losses}


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--engine", default="auto")
    ap.add_argument("--sync", default="auto")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export a Chrome-trace JSON of the run")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="export a metrics snapshot (JSON + .prom)")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="arm a deterministic chaos fault plan, e.g. "
                         "'seed=7,steps=200,payload_corrupt=0.05'")
    args = ap.parse_args()
    out = run_training(TrainConfig(
        arch=args.arch, steps=args.steps, engine=args.engine,
        sync=args.sync, seq_len=args.seq_len, global_batch=args.batch,
        ckpt_dir=args.ckpt_dir, trace_path=args.trace,
        metrics_path=args.metrics, fault_plan=args.faults))
    print(f"final loss: {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
