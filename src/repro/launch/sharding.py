"""Sharding rules: map every pytree leaf to a PartitionSpec.

Generic rule (FSDP × TP, ZeRO over data):
  * pick the largest axis divisible by the 'model' size → TP axis;
  * among the remaining axes, pick the largest divisible by the 'data'
    size → FSDP axis (only for leaves above a size threshold — norms and
    biases replicate);
  * the 'pod' axis (multi-pod mesh) is pure DP for params (replicated) and
    batch-sharded for data — cross-pod traffic is gradient sync only,
    which is exactly where GenTree's plan applies.

Batch / cache rules:
  * leading batch axis shards over all DP axes when divisible;
  * KV caches: KV-head axis over 'model' when divisible, else the sequence
    axis (long-context sequence sharding);
  * recurrent states: channel axis over 'model'.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

REPLICATE_BELOW = 1 << 18       # leaves smaller than 256 Ki elements replicate


def _sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def leaf_spec(shape: tuple[int, ...], mesh: Mesh, *,
              skip_first: bool = True,
              fsdp: bool = True) -> P:
    """Generic TP(+FSDP) spec for a parameter leaf.

    skip_first: axis 0 is the scanned layer-stack axis — never sharded
    (keeps per-layer slices local to the scan)."""
    sz = _sizes(mesh)
    model = sz.get("model", 1)
    data = sz.get("data", 1)
    n = int(np.prod(shape)) if shape else 1
    spec: list[Any] = [None] * len(shape)
    if n < REPLICATE_BELOW or not shape:
        return P(*spec)
    lo = 1 if (skip_first and len(shape) > 1) else 0
    # TP axis: largest axis divisible by model size
    cands = [(shape[i], i) for i in range(lo, len(shape))
             if model > 1 and shape[i] % model == 0]
    ti = None
    if cands:
        _, ti = max(cands)
        spec[ti] = "model"
    # FSDP axis: largest remaining axis divisible by data size
    if fsdp and data > 1:
        cands = [(shape[i], i) for i in range(lo, len(shape))
                 if i != ti and shape[i] % data == 0]
        if cands:
            _, di = max(cands)
            spec[di] = "data"
    return P(*spec)


def params_specs(params: Any, mesh: Mesh, *, fsdp: bool = True) -> Any:
    return jax.tree.map(
        lambda x: leaf_spec(x.shape, mesh, fsdp=fsdp), params)


def opt_specs(opt_state: Any, params_spec_tree: Any,
              mesh: Mesh | None = None) -> Any:
    """Optimizer moments are ALWAYS fully sharded (ZeRO): when params are
    FSDP-sharded they share the spec; when params are replicated over the
    DP axes (ZeRO-1) the moments still shard there — pass `mesh` to derive
    the sharded spec independently of the param spec."""
    if mesh is not None:
        mv = jax.tree.map(
            lambda x: leaf_spec(x.shape, mesh, fsdp=True),
            opt_state["m"])
    else:
        mv = params_spec_tree
    return {"m": mv, "v": mv, "step": P()}


def _dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def _dp_size(mesh: Mesh) -> int:
    sz = _sizes(mesh)
    n = 1
    for a in _dp_axes(mesh):
        n *= sz[a]
    return n


def batch_specs(batch: Any, mesh: Mesh) -> Any:
    """Shard the leading batch axis over the DP axes (mrope_positions has
    batch at axis 1)."""
    dp = _dp_axes(mesh)
    dpn = _dp_size(mesh)

    def spec(path, x) -> P:
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = x.shape
        if name == "mrope_positions":       # (3, B, T)
            return P(None, dp if shape[1] % dpn == 0 else None, None)
        s: list[Any] = [None] * len(shape)
        if shape and shape[0] % dpn == 0 and shape[0] > 1:
            s[0] = dp
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec, batch)


def cache_specs(cache: Any, mesh: Mesh) -> Any:
    """KV caches (L, B, Hkv, S, hd): batch over DP if divisible; then
    KV-heads over 'model' if divisible, else sequence over 'model'.
    Recurrent states (L, B, H|Di, ...): channel axis over 'model'."""
    sz = _sizes(mesh)
    model = sz.get("model", 1)
    dp = _dp_axes(mesh)
    dpn = _dp_size(mesh)

    def spec(path, x) -> P:
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = x.shape
        if name == "pos":
            return P(shape[0] % dpn == 0 and dp or None)
        s: list[Any] = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % dpn == 0 and shape[1] > 1:
            s[1] = dp          # batch axis of (L, B, ...)
        if name in ("k", "v", "xk", "xv") and len(shape) == 5:
            # KV heads over 'model' when divisible, else sequence.
            # Sequence sharding makes the per-token cache update replicate
            # (SPMD can't partition the dynamic-update at `pos`; §Perf
            # iter 12 measured the alternatives — head_dim sharding is
            # WORSE because RoPE/GQA-repeat reshard); the production fix
            # is a paged/ring KV cache with manual decode collectives,
            # out of scope for GSPMD auto-sharding.
            if model > 1 and shape[2] % model == 0:
                s[2] = "model"                  # KV heads
            elif model > 1 and shape[3] % model == 0:
                s[3] = "model"                  # sequence
            # long-context, small batch: spend the idle DP axes on the
            # sequence axis too (e.g. long_500k with global_batch=1)
            if s[1] is None and s[3] is None and len(dp) \
                    and shape[3] % dpn == 0 and shape[3] >= 4 * dpn:
                s[3] = dp
        elif name == "wkv" and len(shape) == 5:
            if model > 1 and shape[2] % model == 0:
                s[2] = "model"                  # wkv heads
        elif name == "ssm" and len(shape) == 4:
            if model > 1 and shape[2] % model == 0:
                s[2] = "model"                  # expanded channels
        elif name in ("tm_shift", "cm_shift") and len(shape) == 4:
            if model > 1 and shape[3] % model == 0:
                s[3] = "model"
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec, cache)


def to_named(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
