"""Numpy-backed checkpointing: atomic, async, step-tagged, resumable.

Layout:  <dir>/step_<k>/arrays.npz + tree.json ; <dir>/LATEST points at the
most recent *complete* save (written last, atomically) so a crash mid-save
never corrupts the restore point. An optional background thread makes
`save` non-blocking (async checkpointing — the train loop keeps stepping
while the previous state snapshot flushes).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np

Pytree = Any


_NP_SAFE = {"float64", "float32", "float16", "int64", "int32", "int16",
            "int8", "uint64", "uint32", "uint16", "uint8", "bool"}


def _to_storable(x: np.ndarray) -> np.ndarray:
    """np.savez can't serialize ml_dtypes (bfloat16, fp8): store the raw
    bits as an unsigned view; tree.json records the true dtype."""
    if str(x.dtype) in _NP_SAFE:
        return x
    return x.view({1: np.uint8, 2: np.uint16, 4: np.uint32,
                   8: np.uint64}[x.dtype.itemsize])


def _file_crc(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            crc = zlib.crc32(chunk, crc)
    return crc & 0xFFFFFFFF


CHECKSUM_FILE = "checksums.json"
_PAYLOAD_FILES = ("arrays.npz", "tree.json")


def write_checksums(path: str) -> None:
    """Record per-file CRC32s for a saved checkpoint dir (written before
    the atomic rename, so a complete dir always carries its manifest)."""
    sums = {name: _file_crc(os.path.join(path, name))
            for name in _PAYLOAD_FILES
            if os.path.exists(os.path.join(path, name))}
    with open(os.path.join(path, CHECKSUM_FILE), "w") as f:
        json.dump({"crc32": sums}, f)


def verify_checksums(path: str) -> bool:
    """True when the dir's payload files match their recorded CRC32s.
    A checkpoint written before checksum manifests existed (no
    checksums.json) passes vacuously — `load_pytree` remains the final
    arbiter; this is the cheap first line (DESIGN.md §12)."""
    manifest = os.path.join(path, CHECKSUM_FILE)
    if not os.path.exists(manifest):
        return all(os.path.exists(os.path.join(path, n))
                   for n in _PAYLOAD_FILES)
    try:
        with open(manifest) as f:
            sums = json.load(f)["crc32"]
        return all(_file_crc(os.path.join(path, name)) == int(want)
                   for name, want in sums.items())
    except (OSError, ValueError, KeyError, TypeError):
        return False


def save_pytree(tree: Pytree, path: str) -> None:
    os.makedirs(path, exist_ok=True)
    flat, treedef = jax.tree.flatten(tree)
    arrs = [np.asarray(x) for x in flat]
    np.savez(os.path.join(path, "arrays.npz"),
             **{f"leaf_{i}": _to_storable(a) for i, a in enumerate(arrs)})
    meta = {
        "treedef": str(treedef),
        "n_leaves": len(arrs),
        "dtypes": [str(a.dtype) for a in arrs],
        "shapes": [list(a.shape) for a in arrs],
    }
    with open(os.path.join(path, "tree.json"), "w") as f:
        json.dump(meta, f)
    write_checksums(path)


def load_pytree(path: str, like: Pytree) -> Pytree:
    """Restore into the structure of `like` (treedef source of truth)."""
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = [z[f"leaf_{i}"] for i in range(len(z.files))]
    with open(os.path.join(path, "tree.json")) as f:
        meta = json.load(f)
    like_flat, treedef = jax.tree.flatten(like)
    assert len(flat) == len(like_flat), \
        f"checkpoint has {len(flat)} leaves, expected {len(like_flat)}"
    import jax.numpy as jnp
    out = []
    for a, dt, l in zip(flat, meta["dtypes"], like_flat):
        if str(a.dtype) != dt:           # stored as raw-bit view
            a = a.view(jnp.dtype(dt))
        out.append(jnp.asarray(a, dtype=l.dtype))
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Pytree) -> None:
        # snapshot to host memory NOW (so the train loop can mutate state)
        host = jax.tree.map(np.asarray, tree)
        if self.async_save:
            self.wait()           # at most one in-flight save
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def _write(self, step: int, host: Pytree) -> None:
        tag = f"step_{step:08d}"
        tmp = os.path.join(self.dir, f".tmp_{tag}")
        final = os.path.join(self.dir, tag)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_pytree(host, tmp)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        # LATEST last: readers never see a partial checkpoint
        latest = os.path.join(self.dir, "LATEST")
        with open(latest + ".tmp", "w") as f:
            f.write(tag)
        os.replace(latest + ".tmp", latest)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> int | None:
        latest = os.path.join(self.dir, "LATEST")
        if not os.path.exists(latest):
            return None
        with open(latest) as f:
            tag = f.read().strip()
        if not os.path.exists(os.path.join(self.dir, tag)):
            return None
        return int(tag.split("_")[1])

    def available_steps(self) -> list[int]:
        """Complete checkpoint steps on disk, newest first."""
        try:
            names = os.listdir(self.dir)
        except OSError:
            return []
        steps = []
        for d in names:
            if d.startswith("step_"):
                try:
                    steps.append(int(d.split("_")[1]))
                except (IndexError, ValueError):
                    continue
        return sorted(steps, reverse=True)

    def verify(self, step: int) -> bool:
        """Checksum-verify one checkpoint dir (see `verify_checksums`)."""
        return verify_checksums(
            os.path.join(self.dir, f"step_{step:08d}"))

    def restore(self, like: Pytree, step: int | None = None
                ) -> tuple[Pytree, int]:
        """Restore the requested (or newest intact) checkpoint.

        An explicit `step` is authoritative: corruption there raises.
        Without one, candidates are tried newest-first; a checkpoint
        failing its checksum manifest or its actual load falls back to
        the previous step (counted in `ckpt_restore_fallbacks_total`) —
        a torn/bit-flipped latest save costs `ckpt_every` steps of
        replay, not the job (DESIGN.md §12)."""
        if step is not None:
            path = os.path.join(self.dir, f"step_{step:08d}")
            return load_pytree(path, like), step
        candidates = self.available_steps()
        if not candidates:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        errors = []
        for cand in candidates:
            path = os.path.join(self.dir, f"step_{cand:08d}")
            try:
                if not verify_checksums(path):
                    raise ValueError(f"checksum mismatch in {path}")
                return load_pytree(path, like), cand
            except Exception as e:      # corrupt/unreadable: try older
                errors.append((cand, repr(e)))
                from repro.runtime.metrics import default_metrics
                default_metrics().counter(
                    "ckpt_restore_fallbacks_total",
                    "corrupt checkpoints skipped during restore").inc()
        raise FileNotFoundError(
            f"no intact checkpoint in {self.dir}; tried {errors}")
