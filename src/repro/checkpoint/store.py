"""Numpy-backed checkpointing: atomic, async, step-tagged, resumable.

Layout:  <dir>/step_<k>/arrays.npz + tree.json ; <dir>/LATEST points at the
most recent *complete* save (written last, atomically) so a crash mid-save
never corrupts the restore point. An optional background thread makes
`save` non-blocking (async checkpointing — the train loop keeps stepping
while the previous state snapshot flushes).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

Pytree = Any


_NP_SAFE = {"float64", "float32", "float16", "int64", "int32", "int16",
            "int8", "uint64", "uint32", "uint16", "uint8", "bool"}


def _to_storable(x: np.ndarray) -> np.ndarray:
    """np.savez can't serialize ml_dtypes (bfloat16, fp8): store the raw
    bits as an unsigned view; tree.json records the true dtype."""
    if str(x.dtype) in _NP_SAFE:
        return x
    return x.view({1: np.uint8, 2: np.uint16, 4: np.uint32,
                   8: np.uint64}[x.dtype.itemsize])


def save_pytree(tree: Pytree, path: str) -> None:
    os.makedirs(path, exist_ok=True)
    flat, treedef = jax.tree.flatten(tree)
    arrs = [np.asarray(x) for x in flat]
    np.savez(os.path.join(path, "arrays.npz"),
             **{f"leaf_{i}": _to_storable(a) for i, a in enumerate(arrs)})
    meta = {
        "treedef": str(treedef),
        "n_leaves": len(arrs),
        "dtypes": [str(a.dtype) for a in arrs],
        "shapes": [list(a.shape) for a in arrs],
    }
    with open(os.path.join(path, "tree.json"), "w") as f:
        json.dump(meta, f)


def load_pytree(path: str, like: Pytree) -> Pytree:
    """Restore into the structure of `like` (treedef source of truth)."""
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = [z[f"leaf_{i}"] for i in range(len(z.files))]
    with open(os.path.join(path, "tree.json")) as f:
        meta = json.load(f)
    like_flat, treedef = jax.tree.flatten(like)
    assert len(flat) == len(like_flat), \
        f"checkpoint has {len(flat)} leaves, expected {len(like_flat)}"
    import jax.numpy as jnp
    out = []
    for a, dt, l in zip(flat, meta["dtypes"], like_flat):
        if str(a.dtype) != dt:           # stored as raw-bit view
            a = a.view(jnp.dtype(dt))
        out.append(jnp.asarray(a, dtype=l.dtype))
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Pytree) -> None:
        # snapshot to host memory NOW (so the train loop can mutate state)
        host = jax.tree.map(np.asarray, tree)
        if self.async_save:
            self.wait()           # at most one in-flight save
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def _write(self, step: int, host: Pytree) -> None:
        tag = f"step_{step:08d}"
        tmp = os.path.join(self.dir, f".tmp_{tag}")
        final = os.path.join(self.dir, tag)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_pytree(host, tmp)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        # LATEST last: readers never see a partial checkpoint
        latest = os.path.join(self.dir, "LATEST")
        with open(latest + ".tmp", "w") as f:
            f.write(tag)
        os.replace(latest + ".tmp", latest)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> int | None:
        latest = os.path.join(self.dir, "LATEST")
        if not os.path.exists(latest):
            return None
        with open(latest) as f:
            tag = f.read().strip()
        if not os.path.exists(os.path.join(self.dir, tag)):
            return None
        return int(tag.split("_")[1])

    def restore(self, like: Pytree, step: int | None = None) -> tuple[Pytree, int]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        return load_pytree(path, like), step
